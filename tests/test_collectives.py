"""User-level collective schedules vs native ops (multi-device subprocess)
+ compression correctness (single device)."""
import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collectives.compression import (
    ErrorFeedback, dequantize_int8, quantize_int8)
from tests._multidevice import run_with_devices


class TestSchedulesMultiDevice:
    def test_allreduce_algorithms_match_psum(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives import schedules as S
            mesh = compat.make_mesh((8,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 33))  # odd last dim
            native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            for alg in S.ALGORITHMS:
                out = jax.jit(lambda v, a=alg: S.allreduce_under_shard_map(v, mesh, "x", a))(x)
                np.testing.assert_allclose(np.asarray(out), np.asarray(native),
                                           atol=1e-4, rtol=1e-4), alg
            print("ALLREDUCE_MATCH")
        """)
        assert "ALLREDUCE_MATCH" in out

    def test_reduce_scatter_all_gather_match_native(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives import schedules as S
            mesh = compat.make_mesh((8,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 64))
            def user(v):
                return S.ring_all_gather(S.ring_reduce_scatter(v, "x"), "x")
            def native(v):
                return jax.lax.all_gather(
                    jax.lax.psum_scatter(v, "x", scatter_dimension=v.ndim-1, tiled=True),
                    "x", axis=v.ndim-1, tiled=True)
            a = jax.jit(compat.shard_map(user, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            b = jax.jit(compat.shard_map(native, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
            print("RS_AG_MATCH")
        """)
        assert "RS_AG_MATCH" in out

    def test_bruck_matches_native_all_to_all(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives import schedules as S
            mesh = compat.make_mesh((8,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
            user = jax.jit(compat.shard_map(lambda v: S.bruck_alltoall(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            native = jax.jit(compat.shard_map(
                lambda v: jax.lax.all_to_all(v.reshape(8, 8 // 8, 16), "x", 0, 0,
                                             tiled=False).reshape(8, 16),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            np.testing.assert_allclose(np.asarray(user), np.asarray(native), atol=1e-6)
            print("BRUCK_MATCH")
        """)
        assert "BRUCK_MATCH" in out

    def test_collective_matmul_ag_matches_reference(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives import overlap as O
            mesh = compat.make_mesh((4,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))   # rows sharded
            w = jax.random.normal(jax.random.PRNGKey(1), (16, 64))   # cols sharded
            user = jax.jit(compat.shard_map(lambda xs, ws: O.collective_matmul_ag(xs, ws, "x"),
                mesh=mesh, in_specs=(P("x"), P(None, "x")), out_specs=P(None, "x")))(x, w)
            ref = x @ w
            np.testing.assert_allclose(np.asarray(user), np.asarray(ref), atol=1e-4)
            print("CM_AG_MATCH")
        """, n_devices=4)
        assert "CM_AG_MATCH" in out

    def test_collective_matmul_rs_matches_reference(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives import overlap as O
            mesh = compat.make_mesh((4,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
            w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
            # contraction sharded: x cols + w rows over "x"; rows scattered out
            user = jax.jit(compat.shard_map(lambda xs, ws: O.collective_matmul_rs(xs, ws, "x"),
                mesh=mesh, in_specs=(P(None, "x"), P("x", None)), out_specs=P("x", None)))(x, w)
            ref = x @ w
            np.testing.assert_allclose(np.asarray(user), np.asarray(ref), atol=1e-3, rtol=1e-4)
            print("CM_RS_MATCH")
        """, n_devices=4)
        assert "CM_RS_MATCH" in out

    def test_compressed_allreduce_multidevice(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from jax.sharding import PartitionSpec as P
            from repro.collectives.compression import compressed_allreduce
            mesh = compat.make_mesh((4,), ("x",))
            x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 512))
            out = jax.jit(compat.shard_map(lambda v: compressed_allreduce(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            exact = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), x.shape)
            rel = np.abs(np.asarray(out) - exact) / (np.abs(exact) + 1e-3)
            assert rel.mean() < 0.05, rel.mean()   # int8: few-% relative error
            print("COMPRESSED_OK")
        """, n_devices=4)
        assert "COMPRESSED_OK" in out


class TestQuantization:
    def test_roundtrip_error_bounded(self, rng):
        x = jax.random.normal(rng, (4096,)) * 3.0
        q, s = quantize_int8(x, block=256)
        xr = dequantize_int8(q, s, x.shape[-1])
        err = jnp.abs(xr - x)
        # max error is one quantization bin = scale
        bins = jnp.repeat(s[..., 0], 256)[:4096]
        assert float(jnp.max(err - bins)) <= 1e-6

    def test_error_feedback_preserves_signal(self, rng):
        """With EF, the accumulated applied update converges to the true
        gradient sum (bias cancels)."""
        ef = ErrorFeedback(axis=None, block=64)
        g_true = jax.random.normal(rng, (512,)) * 1e-3   # small grads
        err = jnp.zeros((512,))
        applied = jnp.zeros((512,))
        for _ in range(20):
            target = g_true + err
            q, s = quantize_int8(target, 64)
            sent = dequantize_int8(q, s, 512)
            err = target - sent
            applied = applied + sent
        # mean applied per step ≈ g_true
        np.testing.assert_allclose(np.asarray(applied / 20),
                                   np.asarray(g_true), atol=2e-4)
