"""Parametrized equivalence: EVERY user-level schedule vs the native op,
on 1/2/4 simulated CPU devices, with odd and power-of-two payload sizes.

Complements test_collectives.py (which pins the 8-device case): the
schedules must also be correct at degenerate (P=1) and small axis sizes,
and for payloads the ring padding path has to handle (odd last dims).
"""
import pytest

from tests._multidevice import run_with_devices


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_allreduce_algorithms_match_psum(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        for D in (33, 64):                      # odd and power-of-two
            x = jax.random.normal(jax.random.PRNGKey(D), (n * 2, 3, D))
            native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            for alg in S.ALGORITHMS:            # ring/bidir/recursive/halving
                out = jax.jit(lambda v, a=alg: S.allreduce_under_shard_map(
                    v, mesh, "x", a))(x)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(native),
                    atol=1e-4, rtol=1e-4, err_msg=f"{{alg}} D={{D}}")
        print("EQUIV_ALLREDUCE_OK")
    """, n_devices=n_devices)
    assert "EQUIV_ALLREDUCE_OK" in out


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_reduce_scatter_all_gather_match_native(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        for D in (n * 3, n * 16):               # odd and power-of-two /P
            x = jax.random.normal(jax.random.PRNGKey(D), (n * 2, 2, D))
            def user(v):
                return S.ring_all_gather(S.ring_reduce_scatter(v, "x"), "x")
            if n == 1:
                native_fn = lambda v: v          # RS+AG on P=1 is identity
            else:
                def native_fn(v):
                    return jax.lax.all_gather(
                        jax.lax.psum_scatter(v, "x",
                                             scatter_dimension=v.ndim - 1,
                                             tiled=True),
                        "x", axis=v.ndim - 1, tiled=True)
            a = jax.jit(compat.shard_map(user, mesh=mesh,
                                         in_specs=P("x"), out_specs=P("x")))(x)
            b = jax.jit(compat.shard_map(native_fn, mesh=mesh,
                                         in_specs=P("x"), out_specs=P("x")))(x)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, err_msg=f"D={{D}}")
        print("EQUIV_RS_AG_OK")
    """, n_devices=n_devices)
    assert "EQUIV_RS_AG_OK" in out


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_bruck_alltoall_matches_native(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        for d in (5, 16):                       # odd and power-of-two blocks
            x = jax.random.normal(jax.random.PRNGKey(d), (n * n, d))
            user = jax.jit(compat.shard_map(
                lambda v: S.bruck_alltoall(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            native = jax.jit(compat.shard_map(
                lambda v: jax.lax.all_to_all(
                    v.reshape(n, 1, d), "x", 0, 0,
                    tiled=False).reshape(n, d),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            np.testing.assert_allclose(np.asarray(user), np.asarray(native),
                                       atol=1e-6, err_msg=f"d={{d}}")
        print("EQUIV_BRUCK_OK")
    """, n_devices=n_devices)
    assert "EQUIV_BRUCK_OK" in out
