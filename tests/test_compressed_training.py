"""End-to-end DP training with int8 error-feedback gradient compression
across the data axis (the cross-pod trick), vs exact reduction."""
from tests._multidevice import run_with_devices


def test_compressed_dp_training_converges_like_exact():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.collectives.compression import (
            compressed_allreduce, dequantize_int8, quantize_int8)

        # toy regression: w [D]; data sharded over 4 devices
        mesh = compat.make_mesh((4,), ("data",))
        D, N = 64, 256
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        w_true = jax.random.normal(ks[0], (D,))
        X = jax.random.normal(ks[1], (N, D))
        y = X @ w_true + 0.01 * jax.random.normal(ks[2], (N,))

        def local_grad(w, Xl, yl):
            r = Xl @ w - yl
            return Xl.T @ r / Xl.shape[0]

        def make_step(compressed):
            def step(w, err, Xs, ys):
                def inner(w, err, Xl, yl):
                    # err: [1, D] — per-device error-feedback state
                    g = local_grad(w, Xl, yl)
                    if compressed:
                        target = g + err[0]
                        q, s = quantize_int8(target, 64)
                        sent = dequantize_int8(q, s, D)
                        new_err = (target - sent)[None]
                        g_red = compressed_allreduce(target, "data", 64) / 4.0
                    else:
                        new_err = err
                        g_red = jax.lax.pmean(g, "data")
                    return w - 0.1 * g_red, new_err
                # check_vma=False: the ring allreduce's output IS
                # replicated, but the varying-axes checker cannot prove
                # replication through ppermute chains
                return compat.shard_map(
                    inner, mesh=mesh,
                    in_specs=(P(), P("data"), P("data"), P("data")),
                    out_specs=(P(), P("data")), check_vma=False)(w, err, Xs, ys)
            return jax.jit(step)

        for compressed in (False, True):
            w = jnp.zeros((D,))
            err = jnp.zeros((4, D))
            step = make_step(compressed)
            for _ in range(400):
                w, err = step(w, err, X, y)
            final = float(jnp.mean((X @ w - y) ** 2))
            print(("COMPRESSED" if compressed else "EXACT"), final)
            assert final < 0.005, (compressed, final)
        print("COMPRESSED_TRAIN_OK")
    """, n_devices=4)
    assert "COMPRESSED_TRAIN_OK" in out
