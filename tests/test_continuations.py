"""Continuation subsystem tests (MPI Continuations on the engine).

Covers both execution policies (inline-on-progress-thread vs deferred
owner drain), failure continuations, chaining (then/when_all/when_any/
node-as-TaskGraph), executor queue adoption, and the continuation
counters surfaced through repro.core.stats.
"""
import threading
import time

import pytest

from repro.core import (
    DEFERRED, DONE, INLINE, NOPROGRESS, CompletionCounter, ContinuationQueue,
    ProgressEngine, ProgressExecutor, Request, stats,
)


def timed_task(duration, req=None, value=None):
    deadline = time.monotonic() + duration

    def poll(thing):
        if time.monotonic() >= deadline:
            if req is not None:
                req.complete(value)
            return DONE
        return NOPROGRESS
    return poll


def failing_task(duration, req, exc):
    deadline = time.monotonic() + duration

    def poll(thing):
        if time.monotonic() >= deadline:
            req.fail(exc)
            return DONE
        return NOPROGRESS
    return poll


def wait_until(pred, timeout=10.0, what="condition"):
    t0 = time.monotonic()
    while not pred():
        time.sleep(0.0005)
        if time.monotonic() - t0 > timeout:
            raise TimeoutError(what)


class TestInlinePolicy:
    def test_fires_on_progress_thread_exactly_once(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        req = Request()
        fired = []
        q.attach(req, lambda r: fired.append(r))
        eng.async_start(timed_task(0.002, req=req, value=41))
        while not fired:
            eng.progress()
        for _ in range(5):
            eng.progress()                    # further sweeps must not refire
        assert fired == [req]
        assert req.value() == 41
        assert q.enqueued == 1 and q.executed == 1 and q.deferred == 0
        assert q.pending == 0 and q.ready == 0

    def test_already_complete_request_fires_immediately(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        req = Request()
        req.complete("now")
        fired = []
        q.attach(req, lambda r: fired.append(r.value()))
        assert fired == ["now"]               # no progress call needed

    def test_queue_task_retires_when_empty(self):
        """No perpetual task: once every continuation fired, the detection
        task returns DONE and the stream goes empty (no idle polling)."""
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        req = Request()
        q.attach(req, lambda r: None)
        req.complete()
        eng.progress()
        eng.progress()
        assert eng.default_stream.pending == 0
        # re-attach re-registers (lazily)
        req2 = Request()
        q.attach(req2, lambda r: None)
        assert eng.default_stream.pending == 1

    def test_callback_exception_recorded_not_raised(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        req = Request()
        q.attach(req, lambda r: 1 / 0)
        req.complete()
        eng.progress()                        # must not raise
        assert len(q.callback_errors) == 1
        assert q.failed == 1
        assert eng.default_stream.task_errors == []   # queue task survived


class TestDeferredPolicy:
    def test_owner_drains_outside_progress_path(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        reqs = [Request() for _ in range(4)]
        fired = []
        for r in reqs:
            q.attach(r, lambda rr: fired.append(rr))
        for r in reqs:
            r.complete()
        eng.progress()
        assert fired == []                    # detection only defers
        assert q.ready == 4 and q.deferred == 4
        assert q.drain() == 4
        assert len(fired) == 4 and set(fired) == set(reqs)

    def test_bounded_drain_backpressure(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        reqs = [Request() for _ in range(10)]
        fired = []
        for r in reqs:
            q.attach(r, lambda rr: fired.append(rr))
            r.complete()
        eng.progress()
        assert q.drain(max_items=3) == 3
        assert len(fired) == 3 and q.ready == 7
        assert q.drain() == 7

    def test_fire_exactly_once_with_concurrent_drainers(self):
        """Two threads draining the same queue never double-execute."""
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        n = 200
        counts = [0] * n
        for i in range(n):
            r = Request()
            q.attach(r, lambda rr, i=i: counts.__setitem__(i, counts[i] + 1))
            r.complete()
        eng.progress()
        assert q.ready == n
        threads = [threading.Thread(target=q.drain) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert counts == [1] * n
        assert q.executed == n


class TestFailureContinuations:
    def test_on_error_routes_failed_requests(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        ok, bad = [], []
        r1, r2 = Request(), Request()
        q.attach(r1, ok.append, on_error=bad.append)
        q.attach(r2, ok.append, on_error=bad.append)
        r1.complete("fine")
        r2.fail(RuntimeError("boom"))
        eng.progress()
        assert ok == [r1] and bad == [r2]
        assert isinstance(r2.exception, RuntimeError)
        assert q.failed == 1

    def test_failed_without_on_error_still_fires_callback(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        seen = []
        r = Request()
        q.attach(r, lambda rr: seen.append(rr.failed))
        r.fail(ValueError("x"))
        eng.progress()
        assert seen == [True]


class TestChaining:
    def test_then_transforms_value(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        r = Request()
        out = q.then(r, lambda v: v * 2)
        r.complete(21)
        eng.progress()
        assert out.is_complete and out.value() == 42

    def test_then_propagates_failure_through_chain(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        r = Request()
        mid = q.then(r, lambda v: v + 1)
        end = q.then(mid, lambda v: v + 1)
        r.fail(RuntimeError("root cause"))
        for _ in range(4):
            eng.progress()
        assert end.failed
        with pytest.raises(RuntimeError, match="root cause"):
            end.value()

    def test_then_on_error_recovers(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        r = Request()
        out = q.then(r, lambda v: v, on_error=lambda exc: "recovered")
        r.fail(RuntimeError("gone"))
        eng.progress()
        assert out.value() == "recovered"

    def test_fn_raising_fails_result(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        r = Request()
        out = q.then(r, lambda v: 1 / 0)
        r.complete(1)
        eng.progress()
        assert out.failed and isinstance(out.exception, ZeroDivisionError)

    def test_when_all_collects_values_in_order(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        reqs = [Request() for _ in range(3)]
        out = q.when_all(reqs)
        for i, r in enumerate(reversed(reqs)):    # complete out of order
            r.complete(i)
        for _ in range(3):
            eng.progress()
        assert out.value() == [2, 1, 0]

    def test_when_any_returns_first_complete(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        reqs = [Request() for _ in range(3)]
        out = q.when_any(reqs)
        reqs[1].complete("mid")
        eng.progress()
        i, r = out.value()
        assert i == 1 and r.value() == "mid"

    def test_node_dag_as_continuations(self):
        """A TaskGraph expressed as continuation nodes: diamond DAG with
        completion-driven scheduling and transitive failure propagation."""
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        order = []
        a = q.node(lambda: (order.append("a"), 1)[1])
        b = q.then(a, lambda v: (order.append("b"), v + 10)[1])
        c = q.then(a, lambda v: (order.append("c"), v + 100)[1])
        d = q.node(lambda bv, cv: (order.append("d"), bv + cv)[1], deps=[b, c])
        for _ in range(6):
            eng.progress()
        assert d.value() == 112
        assert order[0] == "a" and order[-1] == "d"

    def test_node_failure_skips_dependents(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        ran = []
        a = q.node(lambda: 1 / 0)
        b = q.node(lambda av: ran.append(av), deps=[a])
        for _ in range(4):
            eng.progress()
        assert b.failed and isinstance(b.exception, ZeroDivisionError)
        assert ran == []

    def test_attach_to_completion_counter(self):
        """Wait-set aggregate continuation: fires once when ALL requests
        behind the counter completed."""
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=INLINE)
        reqs = [Request() for _ in range(4)]
        cc = CompletionCounter(reqs)
        fired = []
        q.attach_counter(cc, lambda c: fired.append(c.completed))
        for r in reqs[:3]:
            r.complete()
        eng.progress()
        assert fired == []
        reqs[3].complete()
        eng.progress()
        assert fired == [4]


class TestExecutorIntegration:
    def test_workers_drain_adopted_queue_between_polls(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, continuation_max_drain=8)
        s = ex.stream("work")
        q = ContinuationQueue(eng, s, policy=DEFERRED, name="bg")
        ex.adopt_queue(q)
        fired = []
        reqs = [Request() for _ in range(20)]
        for r in reqs:
            q.attach(r, lambda rr: fired.append(rr))
        for r, d in zip(reqs, range(len(reqs))):
            eng.async_start(timed_task(0.001 * (d % 4), req=r), None, s)
        with ex:
            wait_until(lambda: len(fired) == 20, 10, "worker drain")
        assert q.executed == 20 and q.deferred == 20
        assert sum(w.drained for w in ex.worker_stats()) == 20

    def test_executor_drain_includes_ready_continuations(self):
        """shutdown(drain=True) must not leave fired-but-undrained
        continuations behind (Listing 1.2 extended to the queue)."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1)
        s = ex.stream("d")
        q = ContinuationQueue(eng, s, policy=DEFERRED, name="dq")
        ex.adopt_queue(q)
        fired = []
        for _ in range(5):
            r = Request()
            q.attach(r, lambda rr: fired.append(rr))
            eng.async_start(timed_task(0.002, req=r), None, s)
        ex.start()
        ex.shutdown(drain=True, timeout=10)
        assert len(fired) == 5
        assert q.ready == 0

    def test_release_queue(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1)
        q = ContinuationQueue(eng, name="r")
        ex.adopt_queue(q)
        assert q in ex.queues()
        ex.release_queue(q)
        assert q not in ex.queues()
        with pytest.raises(ValueError):
            ex.release_queue(q)


class TestLifecycleAndStats:
    def test_close_cancels_pending_runs_ready(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        fired = []
        done_r, never_r = Request(), Request()
        q.attach(done_r, lambda r: fired.append("done"))
        q.attach(never_r, lambda r: fired.append("never"))
        done_r.complete()
        eng.progress()                       # done_r -> ready
        q.close()
        assert fired == ["done"]
        assert q.cancelled == 1
        with pytest.raises(RuntimeError):
            q.attach(Request(), lambda r: None)
        eng.progress()                       # detection task retires
        assert eng.default_stream.pending == 0

    def test_counters_in_stats_snapshot(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED, name="metered")
        r1, r2 = Request(), Request()
        q.attach(r1, lambda r: None)
        q.attach(r2, lambda r: None, on_error=lambda r: None)
        r1.complete()
        r2.fail(RuntimeError("x"))
        eng.progress()
        q.drain()
        snap = stats.collect(eng)
        cs = snap.continuation_queue("metered")
        assert cs.policy == DEFERRED
        assert cs.enqueued == 2 and cs.executed == 2
        assert cs.deferred == 2 and cs.failed == 1
        assert cs.pending == 0 and cs.ready == 0
        assert "metered" in stats.format_stats(snap)

class TestMultiStreamDags:
    """when_all/when_any DAGs spanning multiple executor-adopted streams
    with a mid-DAG failure: the gate fails exactly once, the downstream
    node sees a failure continuation without running its fn, and sibling
    branches on other streams retire instead of hanging — the error
    contract the 1F1B pipeline schedule leans on."""

    def test_when_all_mid_dag_failure_across_adopted_streams(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s1, s2 = ex.stream("lane1"), ex.stream("lane2")
        q = ContinuationQueue(eng, s1, policy=DEFERRED, name="dag")
        ex.adopt_queue(q)

        a, b = Request(tag="a"), Request(tag="b")
        poison = Request(tag="poison")
        sib1, sib2 = Request(tag="sib1"), Request(tag="sib2")
        eng.async_start(timed_task(0.001, req=a, value="A"), None, s1)
        eng.async_start(timed_task(0.004, req=b, value="B"), None, s2)
        eng.async_start(
            failing_task(0.002, poison, RuntimeError("mid-DAG loss")),
            None, s2)
        eng.async_start(timed_task(0.002, req=sib1, value=1), None, s1)
        eng.async_start(timed_task(0.001, req=sib2, value=2), None, s2)

        gate = q.when_all([a, poison, b])
        ok_fires, err_fires, ran = [], [], []
        q.attach(gate, ok_fires.append,
                 on_error=lambda r: err_fires.append(r.exception))
        downstream = q.node(lambda vals: ran.append(vals), deps=[gate])
        sibling = q.when_all([sib1, sib2])

        with ex:
            wait_until(lambda: sibling.is_complete and downstream.is_complete
                       and (ok_fires or err_fires), 10, "DAG settle")

        # gate fails exactly once, with the poisoned member's exception
        assert ok_fires == [] and len(err_fires) == 1
        assert isinstance(gate.exception, RuntimeError)
        # downstream sees a failure continuation; its fn never ran
        assert downstream.failed and ran == []
        assert isinstance(downstream.exception, RuntimeError)
        # the sibling branch (spanning both streams) completed normally
        assert sibling.value() == [1, 2]
        # the healthy members of the failed gate retired too — no hang
        assert a.value() == "A" and b.value() == "B"

    def test_when_any_winner_beats_late_failure_across_streams(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s1, s2 = ex.stream("fast"), ex.stream("slow")
        q = ContinuationQueue(eng, s2, policy=DEFERRED, name="race")
        ex.adopt_queue(q)
        win, lose = Request(tag="win"), Request(tag="lose")
        eng.async_start(timed_task(0.001, req=win, value="winner"), None, s1)
        eng.async_start(
            failing_task(0.05, lose, RuntimeError("late loss")), None, s2)
        out = q.when_any([lose, win])
        with ex:
            wait_until(lambda: out.is_complete, 10, "when_any winner")
            i, r = out.value()
            assert (i, r.value()) == (1, "winner")
            # the losing branch still retires on its own stream
            wait_until(lambda: lose.is_complete, 10, "loser retires")
        assert lose.failed

    def test_when_any_first_failure_propagates_once(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s1, s2 = ex.stream("w1"), ex.stream("w2")
        q = ContinuationQueue(eng, s1, policy=DEFERRED, name="race2")
        ex.adopt_queue(q)
        bad, slow = Request(tag="bad"), Request(tag="slow")
        eng.async_start(
            failing_task(0.001, bad, ValueError("first loss")), None, s2)
        eng.async_start(timed_task(0.03, req=slow, value="late"), None, s1)
        out = q.when_any([slow, bad])
        errs, oks = [], []
        q.attach(out, oks.append, on_error=lambda r: errs.append(r.exception))
        with ex:
            wait_until(lambda: out.is_complete and (oks or errs),
                       10, "when_any failure")
            assert out.failed and isinstance(out.exception, ValueError)
            assert oks == [] and len(errs) == 1
            # sibling keeps making progress past the failure
            wait_until(lambda: slow.is_complete, 10, "sibling retires")
        assert slow.value() == "late"
