"""Continuous batching on the paged KV cache.

Correctness story, in three tiers:

* model level — ``decode_step_paged`` is BIT-identical to the monolithic
  ``decode_step`` for every family (the paged gather view reduces over
  the same positions once the causal mask zeroes the rest);
* engine level — the ``ServeEngine`` (chunked prefill interleaved with
  decode, admission from a length-bucketed backlog, preemption under
  block pressure) produces token streams invariant to the pool shape: a
  deliberately tight pool matches a roomy preemption-free one, because
  greedy decode is per-lane deterministic and replay rebuilds exactly
  the prompt + generated prefix;
* trace level (slow) — a Poisson arrival trace with hundreds of mixed
  length requests through a deliberately tight block pool: every request
  completes, streams match the roomy-pool reference, preemptions stay
  bounded, and the backlog drains exactly when blocks free.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import trend
from repro.configs import get_config
from repro.core import ProgressEngine
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine, _BucketBacklog
from conftest import reduce_cfg
from tests._multidevice import run_with_devices


@pytest.fixture(scope="module")
def tiny():
    cfg = reduce_cfg(get_config("qwen2-0.5b"), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_prompts(n, vocab, lo=2, hi=12, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, vocab - 1,
                        size=rng.randint(lo, hi)).astype(np.int32)
            for _ in range(n)]


def _serve(cfg, params, prompts, max_new, *, batch_slots=4, max_seq=32,
           submit_gap=None, **kw):
    eng = ProgressEngine()
    srv = ServeEngine(cfg, params, eng, batch_slots=batch_slots,
                      max_seq=max_seq, **kw)
    reqs = [GenRequest(f"r{i}", p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    if submit_gap is None:
        for r in reqs:
            srv.submit(r)
    else:
        t0 = time.perf_counter()
        due = 0.0
        for i, r in enumerate(reqs):
            due += submit_gap[i]
            while time.perf_counter() - t0 < due:
                eng.progress()
            srv.submit(r)
    srv.run_until_idle(timeout=300)
    lat = srv.latency_snapshot()
    sched = srv.scheduler_snapshot()
    srv.close(timeout=60)
    return [list(r.out_tokens) for r in reqs], lat, sched, reqs


# ---------------------------------------------------------------------------
# Model level: paged decode == monolithic decode, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b",
                                  "zamba2-1.2b"])
def test_paged_decode_matches_monolithic_decode(arch):
    cfg = reduce_cfg(get_config(arch), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S, bs = 3, 16, 4
    max_blocks = S // bs
    cache = registry.init_cache(cfg, B, S)
    pcache = registry.init_paged_cache(cfg, B, 1 + B * max_blocks, bs)
    tables = np.zeros((B, max_blocks), np.int32)
    for i in range(B):
        tables[i] = 1 + i * max_blocks + np.arange(max_blocks)
    tables = jnp.asarray(tables)
    fed = jnp.ones((B,), bool)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                              cfg.vocab_size)
    pos = jnp.zeros((B,), jnp.int32)
    for t in range(8):
        cur = toks[:, t:t + 1]
        lg, cache = registry.decode_step(params, cfg, cache, cur, pos)
        lgp, pcache = registry.decode_step_paged(params, cfg, pcache, cur,
                                                 pos, tables, fed)
        assert float(jnp.max(jnp.abs(lg - lgp))) == 0.0, (arch, t)
        pos = pos + 1


def test_fed_mask_freezes_ssm_state():
    """A fused paged call must not advance the recurrent state of lanes
    it did not feed — the prerequisite for interleaving one lane's
    prefill with another's decode in SSM/hybrid families."""
    cfg = reduce_cfg(get_config("mamba2-1.3b"), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = registry.init_paged_cache(cfg, B, 2, 4)
    tables = jnp.zeros((B, 4), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    toks = jnp.asarray([[7], [9]], jnp.int32)
    # feed only lane 0; lane 1 sees a garbage token
    fed = jnp.asarray([True, False])
    _, new_cache = registry.decode_step_paged(params, cfg, cache, toks,
                                              pos, tables, fed)
    for old, new in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(new_cache)):
        # lane 1 state frozen exactly; lane 0 advanced
        assert float(jnp.max(jnp.abs(new[:, 1] - old[:, 1]))) == 0.0
        assert float(jnp.max(jnp.abs(new[:, 0] - old[:, 0]))) > 0.0


# ---------------------------------------------------------------------------
# Engine level: token streams are invariant to the pool shape
# ---------------------------------------------------------------------------

class TestPagedEngineEquivalence:
    def test_streams_match_roomy_pool(self, tiny):
        """Default (roomy, preemption-free) pool vs small blocks: the
        same streams, because block granularity is invisible to greedy
        decode."""
        cfg, params = tiny
        prompts = _mixed_prompts(10, cfg.vocab_size)
        ref, _, _, _ = _serve(cfg, params, prompts, 5)
        got, lat, sched, _ = _serve(cfg, params, prompts, 5,
                                    kv_block_size=8)
        assert got == ref
        assert lat.completed == 10 and lat.failed == 0
        assert sched.admitted >= 10 and sched.prefill_calls > 0

    def test_streams_match_under_preemption(self, tiny):
        """A pool too small for the working set forces evictions; replay
        rebuilds prompt + generated prefix, so streams are unchanged and
        preemption is invisible in the output."""
        cfg, params = tiny
        prompts = _mixed_prompts(12, cfg.vocab_size)
        ref, _, _, _ = _serve(cfg, params, prompts, 12)
        got, lat, sched, reqs = _serve(
            cfg, params, prompts, 12,
            kv_block_size=4, kv_blocks=11, prefill_chunk=4)
        assert got == ref
        assert lat.completed == 12 and lat.failed == 0
        assert sched.preemptions > 0          # pressure actually happened
        assert lat.preempted > 0
        # bounded: the oldest-resident-protected policy cannot thrash —
        # each eviction re-queues a request younger than some survivor
        assert sched.preemptions < 12 * 12
        assert all(r.preemptions < 12 for r in reqs)

    def test_wide_lanes_beat_lane_cap_at_equal_bytes(self, tiny):
        """The continuous-batching claim in miniature: on a pool worth
        2 lanes x 32 positions (16 blocks of 4), opening 8 lanes
        sustains more than 2 residents — block granularity means short
        requests stop paying max_seq."""
        cfg, params = tiny
        prompts = _mixed_prompts(16, cfg.vocab_size, lo=2, hi=8)
        got, lat, sched, _ = _serve(
            cfg, params, prompts, 4, batch_slots=8,
            kv_block_size=4, kv_blocks=17)
        assert lat.completed == 16 and lat.failed == 0
        assert sched.peak_resident > 2

    def test_queue_time_reported(self, tiny):
        cfg, params = tiny
        prompts = _mixed_prompts(8, cfg.vocab_size)
        _, lat, _, _ = _serve(cfg, params, prompts, 4, batch_slots=2,
                              kv_block_size=8)
        # 8 requests through 2 lanes: later arrivals waited measurably
        assert lat.queued_ms_mean is not None
        assert lat.queued_ms_p99 >= lat.queued_ms_p50 >= 0.0


class TestBacklogAndBlocks:
    def test_backlog_drains_exactly_when_blocks_free(self, tiny):
        """A request that does not fit the free pool stays backlogged —
        and is admitted on the step where a resident releases enough
        blocks, not before, not never."""
        cfg, params = tiny
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=32,
                          kv_block_size=4,
                          kv_blocks=9)           # 8 usable = one max_seq
        # resident consumes 6 of 8 blocks (prompt 21 -> ceil(21/4) = 6)
        big = GenRequest("big", np.arange(1, 22, dtype=np.int32),
                         max_new_tokens=2)
        srv.submit(big)
        srv.run_until_idle(timeout=120)
        assert len(big.out_tokens) == 2
        # now occupy 6 blocks with a long-runner, then submit one that
        # needs 3: it must wait in the backlog
        r1 = GenRequest("r1", np.arange(1, 22, dtype=np.int32),
                        max_new_tokens=8)
        d1 = srv.submit(r1)
        r2 = GenRequest("r2", np.arange(1, 10, dtype=np.int32),
                        max_new_tokens=2)
        d2 = srv.submit(r2)
        t0 = time.monotonic()
        while not d2.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 120
        # r2 could only have been admitted after r1 finished and freed
        # its blocks (6 + 3 > 8): its queue time spans r1's decode
        assert d1.is_complete
        assert r2.queued_s > 0
        srv.run_until_idle(timeout=60)
        assert srv.slots.allocator.free_count == 8   # all returned
        srv.close(timeout=60)

    def test_oldest_resident_never_preempted(self, tiny):
        cfg, params = tiny
        prompts = _mixed_prompts(10, cfg.vocab_size, lo=6, hi=12, seed=3)
        _, lat, sched, reqs = _serve(
            cfg, params, prompts, 10, batch_slots=4,
            kv_block_size=4, kv_blocks=11, prefill_chunk=4)
        assert lat.completed == 10
        assert sched.preemptions > 0
        # request 0 is the oldest from submission to completion: the
        # policy protects it for its whole residency
        assert reqs[0].preemptions == 0

    def test_bucket_backlog_orders_by_seq_and_length(self):
        bb = _BucketBacklog()

        def req(seq, n):
            r = GenRequest(f"q{seq}", np.arange(n, dtype=np.int32))
            r.seq = seq
            r.replay = r.prompt
            return r

        bb.push(req(3, 4))
        bb.push(req(1, 5))       # same bucket (len 4..7): ahead of seq 3
        bb.push(req(2, 40))      # different bucket
        assert len(bb) == 3
        # fits-everything: oldest bucket first, FIFO within
        popped = []
        while len(bb):
            r, lane = bb.pop_fitting(lambda r: "lane")
            popped.append(r.seq)
        assert popped == [1, 2, 3]
        # head-of-line bypass: bucket heads that do not fit are skipped
        bb.push(req(1, 40))
        bb.push(req(2, 4))
        r, _ = bb.pop_fitting(
            lambda r: "lane" if len(r.replay) < 10 else None)
        assert r.seq == 2


# ---------------------------------------------------------------------------
# Chaos: failures under the paged engine leak nothing
# ---------------------------------------------------------------------------

class TestPagedChaos:
    def _engine(self, tiny, **kw):
        cfg, params = tiny
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=32,
                          kv_block_size=4, **kw)
        return srv, eng

    def test_prefill_chunk_failure_frees_blocks(self, tiny):
        """Kill the fused call mid-chunk: every mid-prefill request is
        failed exactly once, all blocks and lanes return to the free
        lists, and the engine still serves afterwards."""
        srv, eng = self._engine(tiny)
        usable = srv.slots.allocator.usable_blocks
        real = srv._jit_decode
        calls = {"n": 0}

        def boom(*a):
            calls["n"] += 1
            if calls["n"] >= 2:                  # mid-chunk, not at entry
                raise RuntimeError("prefill chunk boom")
            return real(*a)

        srv._jit_decode = boom
        reqs = [GenRequest(f"c{i}", np.arange(1, 8, dtype=np.int32),
                           max_new_tokens=2) for i in range(3)]
        dones = [srv.submit(r) for r in reqs]
        t0 = time.monotonic()
        while not all(d.is_complete for d in dones):
            eng.progress()
            assert time.monotonic() - t0 < 60
        assert all(d.failed for d in dones)
        # failed exactly once: one terminal transition per request
        snap = srv.latency_snapshot()
        assert snap.failed == 3 and snap.completed == 0
        assert snap.no_first_token == 3
        assert snap.ttft_ms_mean is None         # null-propagated
        assert srv.slots.allocator.free_count == usable
        assert srv.slots.free_count == 4
        assert not srv.slots.allocator.owners()
        srv._jit_decode = real
        ok = srv.submit(GenRequest("ok", np.array([1, 2], np.int32),
                                   max_new_tokens=2))
        srv.run_until_idle(timeout=60)
        assert ok.is_complete and len(ok.value()) == 2
        srv.close(timeout=60)

    def test_decode_dispatch_failure_frees_blocks(self, tiny):
        """Kill the decode dispatch: the step's failure continuation
        fails every decoding request once and releases lanes + blocks;
        TTFT stays null for requests that never produced a token."""
        srv, eng = self._engine(tiny)
        usable = srv.slots.allocator.usable_blocks
        real = srv._jit_decode
        state = {"armed": False}

        def boom(*a):
            # arm after prefill: single-token prompts skip prefill, so
            # the first call IS the decode dispatch
            if state["armed"]:
                raise RuntimeError("decode dispatch boom")
            return real(*a)

        srv._jit_decode = boom
        state["armed"] = True
        reqs = [GenRequest(f"d{i}", np.array([i + 1], np.int32),
                           max_new_tokens=4) for i in range(2)]
        dones = [srv.submit(r) for r in reqs]
        t0 = time.monotonic()
        while not all(d.is_complete for d in dones):
            eng.progress()
            assert time.monotonic() - t0 < 60
        assert all(d.failed for d in dones)
        snap = srv.latency_snapshot()
        assert snap.failed == 2
        assert snap.no_first_token == 2 and snap.ttft_ms_mean is None
        assert srv.slots.allocator.free_count == usable
        assert srv.slots.free_count == 4
        state["armed"] = False
        srv._jit_decode = real
        ok = srv.submit(GenRequest("ok", np.array([3], np.int32),
                                   max_new_tokens=2))
        srv.run_until_idle(timeout=60)
        assert ok.is_complete and len(ok.value()) == 2
        srv.close(timeout=60)

    def test_step_harvest_failure_frees_blocks(self, tiny):
        """A step killed AFTER dispatch (async device error surfacing at
        materialisation) takes the same failure path: no leaked blocks,
        TTFT null-propagated for tokenless requests."""
        srv, eng = self._engine(tiny)
        usable = srv.slots.allocator.usable_blocks
        real = srv._next_ids
        srv._next_ids = lambda logits: (_ for _ in ()).throw(
            RuntimeError("harvest boom"))
        r = GenRequest("h", np.array([5], np.int32), max_new_tokens=4)
        done = srv.submit(r)
        t0 = time.monotonic()
        while not done.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 60
        assert done.failed and "harvest boom" in str(done.exception)
        assert r.first_token_at is None
        assert srv.slots.allocator.free_count == usable
        srv._next_ids = real
        srv.close(timeout=60)


# ---------------------------------------------------------------------------
# Trace level (slow): Poisson arrival stress harness
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_arrival_trace_stress(tiny):
    """Hundreds of mixed-length requests through a tight paged pool:
    every request completes, token streams are bit-identical to the
    roomy preemption-free pool on the same trace, preemptions happen
    and stay bounded, and nothing leaks."""
    cfg, params = tiny
    N = 500
    rng = np.random.RandomState(42)
    prompts = [rng.randint(1, cfg.vocab_size - 1,
                           size=rng.randint(1, 20)).astype(np.int32)
               for _ in range(N)]
    gaps = rng.exponential(0.001, size=N)     # ~1k req/s offered
    ref, ref_lat, _, _ = _serve(cfg, params, prompts, 4, batch_slots=8,
                                max_seq=32, submit_gap=list(gaps))
    assert ref_lat.completed == N
    got, lat, sched, reqs = _serve(
        cfg, params, prompts, 4, batch_slots=8, max_seq=32,
        kv_block_size=4, kv_blocks=25,
        prefill_chunk=4, submit_gap=list(gaps))
    assert got == ref
    assert lat.completed == N and lat.failed == 0
    assert sched.preemptions > 0              # the pool was actually tight
    assert sched.preemptions < 4 * N          # bounded, no thrash
    assert max(r.preemptions for r in reqs) < 20
    assert lat.queued_ms_p99 is not None


@pytest.mark.slow
@pytest.mark.multidevice
@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_arrival_trace_sharded(n_devices):
    """The paged scheduler under model-axis-sharded decode: same trace,
    tight pool streams identical to the roomy sharded engine."""
    out = run_with_devices(f"""
        import jax, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.models import registry
        from repro.serve.engine import GenRequest, ServeEngine

        n = {n_devices}
        cfg = get_config('qwen2-0.5b').with_overrides(
            num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
            num_kv_heads=2, head_dim=16, remat_policy='none')
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        mesh = compat.make_mesh((n,), ('model',))
        rng = np.random.RandomState(7)
        prompts = [rng.randint(1, 63, size=rng.randint(1, 10)).astype(np.int32)
                   for _ in range(40)]

        def serve(**kw):
            eng = ProgressEngine()
            srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=32,
                              mesh=mesh, **kw)
            reqs = [GenRequest(f'r{{i}}', p, max_new_tokens=4)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            srv.run_until_idle(timeout=300)
            lat = srv.latency_snapshot()
            srv.close(timeout=60)
            return [list(r.out_tokens) for r in reqs], lat

        ref, _ = serve()
        got, lat = serve(kv_block_size=4, kv_blocks=17,
                         prefill_chunk=4)
        assert got == ref, 'tight sharded pool diverged from roomy'
        assert lat.completed == 40 and lat.failed == 0
        print('PAGED_SHARDED_TRACE_OK')
    """, n_devices=n_devices)
    assert "PAGED_SHARDED_TRACE_OK" in out


@pytest.mark.slow
def test_trace_ssm_concurrency_consistent():
    """SSM/hybrid families: concurrent continuous batching produces the
    same streams as serial (one-resident-at-a-time) service — the fed
    mask and lane reset isolate recurrent state across interleavings.
    (This is why the retired fixed-slot engine could not serve as a
    reference: its prefill leaked garbage tokens into other lanes'
    SSM states by construction.)"""
    for arch in ("mamba2-1.3b", "zamba2-1.2b"):
        cfg = reduce_cfg(get_config(arch), dtype="float32")
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        prompts = _mixed_prompts(6, cfg.vocab_size, seed=5)
        kw = dict(kv_block_size=8)
        serial = []
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=32, **kw)
        for i, p in enumerate(prompts):       # one resident at a time
            r = GenRequest(f"s{i}", p, max_new_tokens=4)
            srv.submit(r)
            srv.run_until_idle(timeout=120)
            serial.append(list(r.out_tokens))
        srv.close(timeout=60)
        got, lat, _, _ = _serve(cfg, params, prompts, 4, batch_slots=4,
                                max_seq=32, **kw)
        assert got == serial, arch
        assert lat.completed == 6


# ---------------------------------------------------------------------------
# Trend gate: serve_cb rows are tracked, ratio rows are not
# ---------------------------------------------------------------------------

class TestTrendServeCbRows:
    def _summary(self, rows):
        return {"schema": "repro-bench-v1", "git_rev": "x",
                "rows": [{"name": n, "us_per_call": v, "derived": ""}
                         for n, v in rows]}

    def test_serve_cb_rows_tracked(self, tmp_path):
        import json
        prev = tmp_path / "prev.json"
        cur = tmp_path / "cur.json"
        prev.write_text(json.dumps(self._summary(
            [("serve_cb_ttft_paged", 1000.0),
             ("serve_cb_p99_lane4", 5000.0),
             ("cb_gain_concurrency", 3.0)])))
        cur.write_text(json.dumps(self._summary(
            [("serve_cb_ttft_paged", 2500.0),      # regressed
             ("serve_cb_p99_lane4", 5100.0),       # ok
             ("cb_gain_concurrency", 1.0)])))      # ratio: untracked
        prev_rows = trend.load_rows(str(prev), trend.DEFAULT_PREFIXES)
        cur_rows = trend.load_rows(str(cur), trend.DEFAULT_PREFIXES)
        assert "serve_cb_ttft_paged" in prev_rows
        assert "cb_gain_concurrency" not in prev_rows
        by_name = {e["name"]: e
                   for e in trend.compare(prev_rows, cur_rows, 0.2)}
        assert by_name["serve_cb_ttft_paged"]["status"] == "regressed"
        assert by_name["serve_cb_p99_lane4"]["status"] == "ok"
