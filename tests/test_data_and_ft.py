"""Data pipeline prefetch + fault-tolerance monitor tests."""
import time

import numpy as np
import pytest

from repro.core import ProgressEngine
from repro.data.pipeline import PrefetchPipeline, SyntheticLM
from repro.distributed.elastic import plan_mesh
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor, StepWatchdog, StragglerDetector)


class TestSyntheticLM:
    def test_shapes_and_determinism(self):
        src1 = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=1)
        src2 = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=1)
        b1, b2 = src1.sample(), src2.sample()
        assert b1["tokens"].shape == (4, 16)
        assert b1["labels"].shape == (4, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_shifted(self):
        src = SyntheticLM(vocab_size=100, seq_len=16, batch_size=2, seed=0)
        b = src.sample()
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_shards_differ(self):
        a = SyntheticLM(100, 16, 4, seed=1, shard=0, num_shards=2).sample()
        b = SyntheticLM(100, 16, 4, seed=1, shard=1, num_shards=2).sample()
        assert not np.array_equal(a["tokens"], b["tokens"])


class TestPrefetch:
    def test_buffer_fills_via_progress(self):
        eng = ProgressEngine()
        pipe = PrefetchPipeline(SyntheticLM(50, 8, 2), eng, depth=3)
        t0 = time.monotonic()
        while pipe.fills < 3 and time.monotonic() - t0 < 10:
            eng.progress()
        assert pipe.fills >= 3
        b = pipe.next_batch()
        assert b["tokens"].shape == (2, 8)
        pipe.close()

    def test_warm_buffer_no_stall(self):
        eng = ProgressEngine()
        pipe = PrefetchPipeline(SyntheticLM(50, 8, 2), eng, depth=2)
        t0 = time.monotonic()
        while pipe.fills < 2 and time.monotonic() - t0 < 10:
            eng.progress()
        stalls_before = pipe.stalls
        pipe.next_batch()
        assert pipe.stalls == stalls_before     # warm hit
        pipe.close()


class TestHeartbeat:
    def test_failure_detection(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        failed = []
        hb = HeartbeatMonitor(eng, ["pod0", "pod1"], timeout=10.0,
                              on_failure=failed.append,
                              clock=lambda: clock["t"])
        clock["t"] = 5.0
        hb.beat("pod0")
        eng.progress()
        assert failed == []
        clock["t"] = 12.0                   # pod1's last beat at t=0
        eng.progress()
        assert failed == ["pod1"]
        assert hb.alive == ["pod0"]

    def test_recovery_after_beat(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        hb = HeartbeatMonitor(eng, ["p"], timeout=5.0,
                              clock=lambda: clock["t"])
        clock["t"] = 6.0
        eng.progress()
        assert "p" in hb.failed
        hb.beat("p")
        assert "p" not in hb.failed


class TestStraggler:
    def test_flags_slow_steps(self):
        d = StragglerDetector(threshold=1.5)
        for _ in range(10):
            assert not d.record("chip0", 1.0)
        assert d.record("chip7", 2.0)       # 2x the EWMA
        assert not d.record("chip0", 1.05)
        assert d.flagged == {"chip7": 1}

    def test_persistent_stragglers(self):
        d = StragglerDetector(threshold=1.5)
        for _ in range(5):
            d.record("ok", 1.0)
        for _ in range(3):
            d.record("bad", 3.0)
        assert d.persistent_stragglers(min_count=3) == ["bad"]

    def test_ewma_not_poisoned_by_outliers(self):
        d = StragglerDetector(threshold=1.5)
        for _ in range(5):
            d.record("a", 1.0)
        d.record("a", 100.0)                # huge outlier
        assert d.ewma < 1.5                 # mean unaffected


class TestWatchdog:
    def test_fires_on_hang(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        hangs = []
        wd = StepWatchdog(eng, limit=30.0, on_hang=lambda: hangs.append(1),
                          clock=lambda: clock["t"])
        wd.arm()
        clock["t"] = 10.0
        eng.progress()
        assert hangs == []
        clock["t"] = 31.0
        eng.progress()
        assert hangs == [1]

    def test_disarm(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        wd = StepWatchdog(eng, limit=5.0, clock=lambda: clock["t"])
        wd.arm()
        wd.disarm()
        clock["t"] = 100.0
        eng.progress()
        assert wd.fired == 0


class TestElasticPlanning:
    @pytest.mark.parametrize("n,expected", [
        (512, (32, 16)), (256, (16, 16)), (255, (8, 16)),  # lost a chip
        (192, (8, 16)), (48, (2, 16)), (8, (1, 8)), (3, (1, 2)),
    ])
    def test_plan_mesh(self, n, expected):
        shape, axes = plan_mesh(n, prefer_model=16)
        assert shape == expected
        assert axes == ("data", "model")
