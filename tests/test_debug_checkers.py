"""Runtime half of the progress-safety rules (PR 10).

Covers the ``REPRO_DEBUG=1`` checkers in ``repro.core.debug``:

* lock-order graph — a synthetically inverted acquisition raises
  :class:`LockOrderError` on a *single thread*, without the deadlock
  interleaving ever occurring; the observed order round-trips through
  ``save``/``load_order`` and drift shows up in ``diff_order``;
* handle lifecycle tracker — direct true positives for every violation
  family, the lazy-completion settle, and the one tolerated
  invalidate/start race;
* enforcement teeth on the production-unguarded paths (a closed
  ``P2PChannel``'s recv half, a closed ``FsdpReducer``);
* the ``ContinuationQueue.drain`` re-entrancy guard (satellite 2);
* membership churn property test (satellite 3): ``epoch.invalidate``
  racing ``handle.start`` lands in exactly one of the two legal states
  across seeded interleavings, with the tracker staying consistent.
"""
import random
import threading
import time

import jax.numpy as jnp
import pytest

from repro import compat
from repro.collectives import nonblocking as NB
from repro.collectives.overlap import FsdpReducer
from repro.collectives.p2p import P2P
from repro.core import (DEFERRED, ContinuationQueue, ProgressEngine,
                        ProgressExecutor, Request, debug)
from repro.core.debug import (HANDLES, LOCK_GRAPH, HandleTracker,
                              LifecycleError, LockOrderError, LockOrderGraph,
                              OrderedLock, diff_order, load_order, make_lock)


@pytest.fixture
def debug_mode():
    prev = debug.set_debug(True)
    HANDLES.reset()
    LOCK_GRAPH.reset()
    try:
        yield
    finally:
        debug.set_debug(prev)
        HANDLES.reset()
        LOCK_GRAPH.reset()


class _Plain:
    """Weakref-able stand-in handle for direct tracker tests."""


# ---------------------------------------------------------------------------
# Lock-order graph
# ---------------------------------------------------------------------------

class TestLockOrder:
    def test_make_lock_obeys_debug_flag(self, debug_mode):
        assert isinstance(make_lock("X._l"), OrderedLock)
        prev = debug.set_debug(False)
        try:
            assert isinstance(make_lock("X._l"), type(threading.Lock()))
        finally:
            debug.set_debug(prev)

    def test_inversion_detected_without_deadlock_interleaving(self):
        # one thread, no races: the AB edge is recorded, the BA attempt
        # raises before blocking — the deadlock schedule never runs
        g = LockOrderGraph()
        a, b = OrderedLock("A", g), OrderedLock("B", g)
        with a:
            with b:
                pass
        with b:
            with pytest.raises(LockOrderError, match="inversion"):
                a.acquire()
        assert not a.locked()  # the failed acquire never took the lock

    def test_consistent_reuse_is_silent(self):
        g = LockOrderGraph()
        a, b, c = (OrderedLock(n, g) for n in "ABC")
        for _ in range(3):
            with a:
                with b:
                    with c:
                        pass
        assert g.snapshot() == {"A": ["B", "C"], "B": ["C"]}

    def test_transitive_cycle_detected(self):
        # A->B and B->C established; C->A closes the cycle transitively
        g = LockOrderGraph()
        a, b, c = (OrderedLock(n, g) for n in "ABC")
        with a:
            with b:
                pass
        with b:
            with c:
                pass
        with c:
            with pytest.raises(LockOrderError, match="A"):
                a.acquire()

    def test_order_persists_and_diffs(self, tmp_path):
        g = LockOrderGraph()
        a, b, c = (OrderedLock(n, g) for n in "ABC")
        with a:
            with b:
                pass
        with b:
            with c:
                pass
        path = str(tmp_path / "lock_order.json")
        g.save(path)
        pinned = load_order(path)
        assert diff_order(pinned, g.snapshot()) == {"added": [],
                                                    "removed": []}
        with a:                      # new edge = drift the diff flags
            with c:
                pass
        assert diff_order(pinned, g.snapshot())["added"] == [("A", "C")]

    def test_engine_lock_roles_inverted(self, debug_mode):
        # real lock roles (constructed under debug => OrderedLock on the
        # global graph): epoch->queue established, queue->epoch raises
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        epoch = NB.MembershipEpoch(n_devices=1)
        with epoch._lock:
            with q._lock:
                pass
        with q._lock:
            with pytest.raises(LockOrderError, match="MembershipEpoch"):
                epoch._lock.acquire()

    def test_observed_engine_order_roundtrips(self, debug_mode, tmp_path):
        # exercise executor + queue under debug, then pin the observed
        # acquisition DAG and verify a reload diffs clean
        eng = ProgressEngine()
        with ProgressExecutor(eng, num_workers=2) as ex:
            q = ContinuationQueue(eng, ex.stream("cq"), policy=DEFERRED)
            ex.adopt_queue(q)
            req = Request(tag="t")
            fired = []
            q.attach(req, fired.append)
            req.complete(1)
            deadline = time.monotonic() + 10
            while not fired and time.monotonic() < deadline:
                time.sleep(1e-3)
            assert fired
        path = str(tmp_path / "engine_order.json")
        LOCK_GRAPH.save(path)
        assert diff_order(load_order(path), LOCK_GRAPH.snapshot()) == \
            {"added": [], "removed": []}


# ---------------------------------------------------------------------------
# Handle lifecycle tracker (direct API)
# ---------------------------------------------------------------------------

class TestHandleTracker:
    def make(self):
        t = HandleTracker()
        h = _Plain()
        t.track(h, "TestHandle")
        return t, h

    def test_double_start(self):
        t, h = self.make()
        t.event(h, "start")
        with pytest.raises(LifecycleError, match="double-start"):
            t.event(h, "start")
        assert t.violations == 1

    def test_start_after_invalidate_without_rebuild(self):
        t, h = self.make()
        t.event(h, "invalidate")
        with pytest.raises(LifecycleError,
                           match="start-after-invalidate-without-rebuild"):
            t.event(h, "start")

    def test_use_after_close(self):
        t, h = self.make()
        t.event(h, "close")
        with pytest.raises(LifecycleError, match="use-after-close"):
            t.event(h, "start")
        with pytest.raises(LifecycleError, match="use-after-close"):
            t.check_open(h, "recv.start")

    def test_wait_without_start(self):
        t, h = self.make()
        with pytest.raises(LifecycleError, match="wait-without-start"):
            t.event(h, "wait")

    def test_legal_cycle_and_lazy_completion(self):
        t, h = self.make()
        t.event(h, "start")
        # nothing reported completion, but the probe confirms the start
        # retired — restart settles ACTIVE -> IDLE -> ACTIVE silently
        assert t.event(h, "start", complete_probe=lambda: True) == "active"
        t.event(h, "invalidate")
        t.event(h, "rebuild")
        t.event(h, "start")
        t.event(h, "wait")
        t.event(h, "close")
        t.event(h, "close")          # idempotent
        assert t.violations == 0

    def test_racing_invalidate_tolerance(self):
        t, h = self.make()
        t.event(h, "invalidate")
        # the one benign race: a start that passed its version check
        # before the invalidation hook landed — tolerated, not flagged
        assert t.event(h, "start", racing_invalidate=True) == "active"
        assert t.violations == 0

    def test_weak_keyed(self):
        t = HandleTracker()
        h = _Plain()
        t.track(h, "TestHandle")
        assert t.state(h) == "idle"
        del h
        import gc
        gc.collect()
        assert len(t._entries) == 0


# ---------------------------------------------------------------------------
# Enforcement on the production-unguarded paths
# ---------------------------------------------------------------------------

def _one_device_handle(epoch=None):
    mesh = compat.make_mesh((1,), ("x",))
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    h = coll.allreduce_init(jnp.zeros((2, 4), jnp.float32), mesh, "x",
                            epoch=epoch, warmup=False)
    return mesh, eng, coll, h


class TestRuntimeHooks:
    def test_tracker_mirrors_persistent_lifecycle(self, debug_mode):
        epoch = NB.MembershipEpoch(n_devices=1)
        mesh, eng, coll, h = _one_device_handle(epoch)
        x = jnp.ones((2, 4), jnp.float32)
        assert HANDLES.state(h) == "idle"
        r = h.start(x)
        assert HANDLES.state(h) == "active"
        r.wait(timeout=30)
        h.start(x).wait(timeout=30)   # restart settles via the probe
        epoch.invalidate(survivors=1, reason="unit")
        assert HANDLES.state(h) == "stale"
        h.rebuild(mesh)
        assert HANDLES.state(h) == "idle"
        h.close()
        assert HANDLES.state(h) == "closed"
        assert HANDLES.violations == 0

    def test_p2p_recv_on_closed_channel_raises(self, debug_mode):
        eng = ProgressEngine()
        p2p = P2P(eng)
        mesh = compat.make_mesh((1,), ("x",))
        like = jnp.zeros((1, 3), jnp.float32)
        chan = p2p.channel_init(like, mesh, "x", warmup=False)
        chan.close()
        # production never guards the recv half (it only touches the
        # overlay queues) — a recv on a closed channel parks forever;
        # the tracker turns that into an immediate error
        with pytest.raises(LifecycleError, match="use-after-close"):
            chan._start_recv()

    def test_fsdp_reducer_use_after_close(self, debug_mode):
        eng = ProgressEngine()
        mesh = compat.make_mesh((1,), ("x",))
        red = FsdpReducer(mesh, "x", engine=eng)
        red.close()
        with pytest.raises(LifecycleError, match="use-after-close"):
            red.ireduce_scatter([jnp.zeros((1, 8), jnp.float32)])
        with pytest.raises(LifecycleError, match="use-after-close"):
            red.igather([jnp.zeros((1, 8), jnp.float32)])


# ---------------------------------------------------------------------------
# ContinuationQueue.drain re-entrancy guard (satellite 2)
# ---------------------------------------------------------------------------

class TestDrainReentrancy:
    def test_reentrant_drain_raises_and_is_recorded(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED, name="reent")
        req = Request(tag="t")
        req.complete(1)
        hits = []

        def body(r):
            hits.append(r)
            q.drain()                # re-entrant: must raise, not recurse

        q.attach(req, body)
        n = q.drain()
        assert n == 1 and len(hits) == 1
        errs = [e for e in q.callback_errors
                if "re-entrant drain" in str(e)]
        assert len(errs) == 1 and isinstance(errs[0], RuntimeError)
        # the guard cleans up: the queue keeps working afterwards
        req2 = Request(tag="t2")
        req2.complete(2)
        got = []
        q.attach(req2, got.append)
        assert q.drain() == 1 and len(got) == 1

    def test_direct_reentry_raises_to_caller(self):
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        req = Request(tag="t")
        req.complete(1)

        seen = []

        def body(r):
            with pytest.raises(RuntimeError, match="re-entrant drain"):
                q.drain()
            seen.append(r)

        q.attach(req, body)
        q.drain()
        assert seen  # the raise happened inside the body, synchronously

    def test_other_threads_may_drain_concurrently(self):
        # the guard is per-thread: a different thread draining the same
        # queue is the normal executor/owner handoff, not re-entrancy
        eng = ProgressEngine()
        q = ContinuationQueue(eng, policy=DEFERRED)
        req = Request(tag="t")
        req.complete(1)
        result = {}

        def body(r):
            t = threading.Thread(
                target=lambda: result.setdefault("n", q.drain()))
            t.start()
            t.join(10)

        q.attach(req, body)
        q.drain()
        assert result["n"] == 0      # nothing left, but no error either
        assert not q.callback_errors


# ---------------------------------------------------------------------------
# Satellite 3: membership churn racing start() — property test
# ---------------------------------------------------------------------------

class TestChurnProperty:
    @pytest.mark.parametrize("seed", range(12))
    def test_invalidate_racing_start_lands_in_one_legal_state(
            self, debug_mode, seed):
        rng = random.Random(seed)
        d_start, d_inval = rng.random() * 2e-3, rng.random() * 2e-3
        epoch = NB.MembershipEpoch(n_devices=1)
        mesh, eng, coll, h = _one_device_handle(epoch)
        x = jnp.ones((2, 4), jnp.float32)
        barrier = threading.Barrier(2)
        out = {}

        def starter():
            barrier.wait()
            time.sleep(d_start)
            try:
                out["req"] = h.start(x)
            except NB.MembershipError as exc:
                out["start_exc"] = exc

        def invalidator():
            barrier.wait()
            time.sleep(d_inval)
            epoch.invalidate(survivors=1, reason=f"churn seed {seed}")

        threads = [threading.Thread(target=starter),
                   threading.Thread(target=invalidator)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not any(t.is_alive() for t in threads)

        # exactly one of the two legal states:
        #   (a) start observed the stale epoch and raised MembershipError
        #   (b) start returned a request that either completed or was
        #       failed exactly once with MembershipError
        assert ("req" in out) ^ ("start_exc" in out), out
        if "req" in out:
            try:
                val = out["req"].wait(timeout=60)
                assert float(jnp.sum(val)) == 8.0
            except NB.MembershipError:
                pass                 # failed-in-flight: legal state (b)
        assert h.stale               # the invalidation always lands
        assert coll.failed <= 1      # exactly-once failure, never double
        # the tracker never mistook the benign race for a violation and
        # its final state is one of the machine's reachable states
        assert HANDLES.violations == 0
        assert HANDLES.state(h) in ("stale", "active", "idle")

        # and the handle recovers: rebuild -> clean start
        h.rebuild(mesh)
        got = h.start(x).wait(timeout=60)
        assert float(jnp.sum(got)) == 8.0
        assert HANDLES.violations == 0
