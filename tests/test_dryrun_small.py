"""Launch-path integration: a miniature dry-run (4×2 mesh, reduced
configs) exercising build_cell/lowering/HLO analysis in a subprocess."""
import pytest

from tests._multidevice import run_with_devices


def _mini_dryrun(arch: str, kind: str, extra: str = "") -> str:
    return run_with_devices(f"""
        import jax
        from repro.configs import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        from repro.analysis import hlo

        cfg = get_config("{arch}")
        kw = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=256,
                  remat_policy="full")
        if cfg.num_heads:
            kw.update(num_heads=4, num_kv_heads=2, head_dim=16)
        if cfg.moe:
            kw["moe"] = cfg.moe.__class__(num_experts=4, top_k=2,
                                          expert_d_ff=64, group_size=64)
        if cfg.ssm:
            kw["ssm"] = cfg.ssm.__class__(d_state=16, expand=2, head_dim=16,
                                          chunk_size=16)
        if cfg.shared_attn_every:
            kw.update(num_layers=4, shared_attn_every=2, shared_attn_lora_rank=4)
        if cfg.is_encoder_decoder:
            kw.update(num_encoder_layers=2, encoder_frames=16,
                      max_position_embeddings=256)
        cfg = cfg.with_overrides(**kw)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = ShapeSpec("t", seq_len=64, global_batch=8, kind="{kind}")
        cell = build_cell(cfg, shape, mesh{extra})
        compiled = cell.lower().compile()
        mem = compiled.memory_analysis()
        res = hlo.analyze(compiled.as_text())
        assert res["flops"] > 0
        assert mem.temp_size_in_bytes >= 0
        print("MINI_DRYRUN_OK", int(res["flops"]),
              round(res["collective_bytes_total"] / 1e3, 1))
    """)


@pytest.mark.parametrize("arch,kind", [
    ("smollm-360m", "train"),
    ("granite-moe-3b-a800m", "train"),
    ("mamba2-1.3b", "train"),
    ("zamba2-1.2b", "decode"),
    ("whisper-tiny", "decode"),
    ("qwen2-0.5b", "prefill"),
])
def test_mini_dryrun(arch, kind):
    out = _mini_dryrun(arch, kind)
    assert "MINI_DRYRUN_OK" in out


def test_mini_dryrun_with_optim_knobs():
    out = _mini_dryrun("smollm-360m", "train",
                       extra=", cast_params_bf16=True, microbatches=2")
    assert "MINI_DRYRUN_OK" in out


def test_mini_dryrun_decode_ws():
    out = _mini_dryrun("qwen2-0.5b", "decode",
                       extra=", decode_weight_stationary=True")
    assert "MINI_DRYRUN_OK" in out
