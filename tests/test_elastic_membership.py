"""Membership-aware persistent collectives + engine-wired fault tolerance.

Four tiers:

* epoch/handle level — ``MembershipEpoch.invalidate`` fails an in-flight
  persistent start exactly once with a retryable ``MembershipError``,
  marks the handle stale until ``rebuild``, and notifies listeners only
  after the handles are failed;
* monitor level — ``HeartbeatMonitor`` survives a concurrent
  ``beat()``/``_poll()`` hammer, ``StepWatchdog`` is one-shot per arm
  (disarm-before-callbacks), and the elastic planners reject impossible
  survivor counts loudly;
* model level — the fixed-slot decode path honours the ``fed`` mask,
  so batched prefill cannot advance the recurrent state of SSM lanes it
  did not feed (the latent bug the paged path already guarded against);
* chaos level (slow) — kill devices mid-decode, mid-prefill and
  mid-gather: the serve engine drains, checkpoints resident lanes,
  remeshes onto the survivors and re-admits, and every token stream is
  bit-identical to an undisturbed run; the trainer's post-failure loss
  trajectory is bit-identical to a from-checkpoint restart on the same
  surviving mesh.
"""
import threading
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collectives import nonblocking as NB
from repro.configs import get_config
from repro.core import ProgressEngine
from repro.distributed import elastic
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor, StepWatchdog, StragglerDetector)
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine
from conftest import reduce_cfg
from tests._multidevice import run_with_devices


# ---------------------------------------------------------------------------
# Epoch / handle lifecycle
# ---------------------------------------------------------------------------

def _one_device_handle(epoch=None, **kw):
    from repro import compat
    mesh = compat.make_mesh((1,), ("x",))
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    h = coll.allreduce_init(jnp.zeros((2, 4), jnp.float32), mesh, "x",
                            epoch=epoch, warmup=False, **kw)
    return mesh, coll, h


class TestMembershipEpoch:
    def test_stale_handle_raises_until_rebuild(self):
        epoch = NB.MembershipEpoch(n_devices=1)
        mesh, coll, h = _one_device_handle(epoch)
        out = h.start(jnp.ones((2, 4), jnp.float32)).wait(timeout=30)
        assert float(jnp.sum(out)) == 8.0
        exc = epoch.invalidate(survivors=1, reason="unit test")
        assert exc.survivors == 1 and exc.version == 1
        assert h.stale
        with pytest.raises(NB.MembershipError) as ei:
            h.start(jnp.ones((2, 4), jnp.float32))
        assert ei.value.survivors == 1 and ei.value.version == 1
        h.rebuild(mesh)
        assert not h.stale and h.rebuilds == 1
        out = h.start(jnp.ones((2, 4), jnp.float32)).wait(timeout=30)
        assert float(jnp.sum(out)) == 8.0
        coll.close()

    def test_invalidate_fails_inflight_start_exactly_once(self):
        """The in-flight start is failed retryably; a second invalidation
        does not double-fail the (already complete) request."""
        from tests.test_persistent_collectives import make_handle
        gate = {"open": False}
        blocker = types.SimpleNamespace(is_ready=lambda: gate["open"])
        coll, h = make_handle([lambda v: blocker, lambda v: v])
        epoch = NB.MembershipEpoch(n_devices=4)
        epoch.register(h)
        h.epoch = epoch
        h._epoch_version = epoch.version
        req = h.start(1.0)
        assert not req.is_complete
        epoch.invalidate(survivors=3, reason="peer died")
        assert req.is_complete and req.failed
        with pytest.raises(NB.MembershipError) as ei:
            req.value()
        assert ei.value.survivors == 3
        failed_before = coll.failed
        epoch.invalidate(survivors=2)
        assert coll.failed == failed_before      # no double-fail
        gate["open"] = True                      # abandoned round retires
        coll.close()

    def test_listeners_run_after_handles_failed(self):
        from tests.test_persistent_collectives import make_handle
        gate = {"open": False}
        blocker = types.SimpleNamespace(is_ready=lambda: gate["open"])
        coll, h = make_handle([lambda v: blocker, lambda v: v])
        epoch = NB.MembershipEpoch(n_devices=2)
        epoch.register(h)
        h.epoch = epoch
        h._epoch_version = epoch.version
        seen = []
        epoch.subscribe(lambda ep, exc: seen.append(
            (ep.version, exc.survivors, h.active.is_complete)))
        req = h.start(1.0)
        assert not req.is_complete
        epoch.invalidate(survivors=1)
        # the listener observed the handle's start already failed
        assert seen == [(1, 1, True)]
        gate["open"] = True
        coll.close()

    def test_epoch_tracks_survivor_count(self):
        epoch = NB.MembershipEpoch(n_devices=8)
        assert epoch.n_devices == 8 and epoch.version == 0
        epoch.invalidate(survivors=5)
        epoch.invalidate(survivors=3)
        assert epoch.n_devices == 3 and epoch.version == 2
        assert epoch.invalidations == 2


# ---------------------------------------------------------------------------
# Monitors
# ---------------------------------------------------------------------------

class TestHeartbeatRace:
    def test_concurrent_beat_and_poll(self):
        """Hammer beat() from worker threads while _poll sweeps with an
        advancing clock right at the timeout edge: no deadlock, no
        permanently-lost peer (the final beat always revives)."""
        eng = ProgressEngine()
        clock = {"t": 0.0}
        lock = threading.Lock()

        def now():
            with lock:
                return clock["t"]

        hb = HeartbeatMonitor(eng, ["p0", "p1"], timeout=1.0, clock=now)
        stop = threading.Event()

        def beater():
            while not stop.is_set():
                hb.beat("p0")

        threads = [threading.Thread(target=beater) for _ in range(4)]
        for t in threads:
            t.start()
        try:
            for _ in range(200):
                with lock:
                    clock["t"] += 0.6       # p1 dies; p0 is kept alive
                eng.progress()
        finally:
            stop.set()
            for t in threads:
                t.join()
        assert "p1" in hb.failed
        hb.beat("p0")
        assert "p0" in hb.alive

    def test_dead_peer_invalidates_epoch_with_device_count(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        epoch = NB.MembershipEpoch(n_devices=8)
        hb = HeartbeatMonitor(eng, [f"h{i}" for i in range(4)], timeout=5.0,
                              clock=lambda: clock["t"], epoch=epoch,
                              devices_per_peer=2)
        clock["t"] = 3.0
        for i in range(3):
            hb.beat(f"h{i}")                # h3 silent
        clock["t"] = 6.0
        eng.progress()
        assert epoch.version == 1
        assert epoch.n_devices == 6         # 3 peers x 2 devices


class TestWatchdogOneShot:
    def test_disarm_after_fire_no_refire(self):
        eng = ProgressEngine()
        clock = {"t": 0.0}
        epoch = NB.MembershipEpoch(n_devices=4)
        wd = StepWatchdog(eng, limit=10.0, clock=lambda: clock["t"],
                          epoch=epoch)
        wd.arm()
        clock["t"] = 11.0
        eng.progress()
        assert wd.fired == 1
        # a hung step keeps the membership: survivors == current devices
        assert epoch.version == 1 and epoch.n_devices == 4
        # further sweeps without re-arm must NOT refire
        clock["t"] = 1000.0
        eng.progress()
        eng.progress()
        assert wd.fired == 1 and epoch.version == 1
        wd.arm()
        clock["t"] = 2000.0
        eng.progress()
        assert wd.fired == 2 and epoch.version == 2

    def test_handler_progressing_engine_does_not_refire(self):
        """on_hang may itself progress the engine (restart machinery):
        the disarm-before-callback ordering keeps firing one-shot."""
        eng = ProgressEngine()
        clock = {"t": 0.0}
        wd = StepWatchdog(eng, limit=5.0, clock=lambda: clock["t"],
                          on_hang=lambda: eng.progress())
        wd.arm()
        clock["t"] = 6.0
        eng.progress()
        assert wd.fired == 1


class TestElasticValidation:
    def test_largest_pof2_rejects_zero(self):
        with pytest.raises(ValueError, match="n >= 1"):
            elastic.largest_pof2(0)

    def test_plan_mesh_rejects_total_loss(self):
        with pytest.raises(ValueError, match="at least 1"):
            elastic.plan_mesh(0)
        with pytest.raises(ValueError, match="at least 1"):
            elastic.plan_mesh(-3)

    def test_remesh_rejects_total_loss(self):
        with pytest.raises(ValueError, match="at least 1"):
            elastic.remesh(0)


class TestStragglerBounds:
    def test_history_and_flagged_bounded(self):
        d = StragglerDetector(threshold=1.5, history_maxlen=8)
        for i in range(100):
            d.record(f"src{i}", 1.0 if i < 5 else 10.0 + i)
        assert len(d.history) <= 8
        assert len(d.flagged) <= 8

    def test_flagged_evicts_least_recent(self):
        d = StragglerDetector(threshold=1.5, history_maxlen=2)
        for _ in range(5):
            d.record("ok", 1.0)
        d.record("a", 10.0)
        d.record("b", 10.0)
        d.record("c", 10.0)
        assert set(d.flagged) == {"b", "c"}   # "a" evicted (LRU)


# ---------------------------------------------------------------------------
# Model level: fed mask on the fixed-slot decode path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_fed_mask_freezes_slot_ssm_state(arch):
    """The latent fixed-slot bug: a batched call feeding only some lanes
    must not advance the recurrent state of the others.  Mirrors the
    paged-path guard (test_continuous_batching) on the SLOT cache."""
    cfg = reduce_cfg(get_config(arch), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    cache = registry.init_cache(cfg, 2, 16)
    # advance both lanes once so the state is non-trivial
    toks = jnp.asarray([[5], [6]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    _, cache = registry.decode_step(params, cfg, cache, toks, pos)
    # now feed ONLY lane 0; lane 1 sees a garbage token
    fed = jnp.asarray([True, False])
    _, new_cache = registry.decode_step(params, cfg, cache,
                                        jnp.asarray([[7], [9]], jnp.int32),
                                        pos + 1, fed)
    flat_old = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_new = jax.tree_util.tree_flatten_with_path(new_cache)[0]
    checked = 0
    for (path, old), (_, new) in zip(flat_old, flat_new):
        # mamba's slot cache IS the state tree; hybrid nests it under
        # ssm/tail_ssm next to attention KV (which is position-safe and
        # legitimately written for unfed lanes)
        if cfg.family != "ssm" and "ssm" not in jax.tree_util.keystr(path):
            continue
        checked += 1
        assert float(jnp.max(jnp.abs(new[:, 1] - old[:, 1]))) == 0.0
        assert float(jnp.max(jnp.abs(new[:, 0] - old[:, 0]))) > 0.0
    assert checked > 0


def test_reset_cache_lane_zeroes_recycled_slot():
    cfg = reduce_cfg(get_config("mamba2-1.3b"), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    cache = registry.init_cache(cfg, 2, 16)
    toks = jnp.asarray([[5], [6]], jnp.int32)
    _, cache = registry.decode_step(params, cfg, cache, toks,
                                    jnp.zeros((2,), jnp.int32))
    cache = registry.reset_cache_lane(cfg, cache, 1)
    for leaf in jax.tree_util.tree_leaves(cache):
        assert float(jnp.max(jnp.abs(leaf[:, 1]))) == 0.0
        assert float(jnp.max(jnp.abs(leaf[:, 0]))) > 0.0


def _serve_streams(cfg, params, prompts, max_new, *, staggered=False, **kw):
    eng = ProgressEngine()
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_seq", 32)
    srv = ServeEngine(cfg, params, eng, **kw)
    reqs = [GenRequest(f"r{i}", p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    if staggered:
        # submit the second request only once the first is mid-decode, so
        # its prefill interleaves with the first lane's decode steps
        srv.submit(reqs[0])
        t0 = time.monotonic()
        while len(reqs[0].out_tokens) < 2 and time.monotonic() - t0 < 120:
            eng.progress()
        assert len(reqs[0].out_tokens) >= 2
        for r in reqs[1:]:
            srv.submit(r)
    else:
        for r in reqs:
            srv.submit(r)
    srv.run_until_idle(timeout=300)
    lat = srv.latency_snapshot()
    srv.close(timeout=60)
    return [list(r.out_tokens) for r in reqs], lat


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_slot_engine_interleaved_prefill_regression(arch):
    """Serve-level regression for the fed-mask fix: prefilling request B
    while request A decodes must leave A's stream bit-identical to A
    served in isolation (SSM state frozen for non-fed lanes)."""
    cfg = reduce_cfg(get_config(arch), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(1, cfg.vocab_size - 1, size=n).astype(np.int32)
               for n in (5, 9)]
    ref = [_serve_streams(cfg, params, [p], 6)[0][0] for p in prompts]
    got, lat = _serve_streams(cfg, params, prompts, 6, staggered=True)
    assert got == ref
    assert lat.completed == 2 and lat.failed == 0


# ---------------------------------------------------------------------------
# KV lane checkpoint/restore (the migration primitive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b",
                                  "zamba2-1.2b"])
def test_kv_lane_checkpoint_restore_roundtrip(arch):
    from repro.serve.kvcache import PagedKVCache
    cfg = reduce_cfg(get_config(arch), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    pool = PagedKVCache(cfg, lanes=2, max_seq=32, block_size=4)
    lane = pool.assign("req", seq_len=1)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 1,
                              cfg.vocab_size)
    pos = jnp.zeros((2,), jnp.int32)
    fed = jnp.asarray([True, False])
    # feed 6 tokens into lane 0, growing its table as we go
    for t in range(6):
        assert pool.ensure(lane.index, t)
        tables = jnp.asarray(pool.block_tables())
        _, pool.cache = registry.decode_step_paged(
            params, cfg, pool.cache, toks, pos + t, tables, fed)
        lane.pos = t + 1
    ckpt = pool.checkpoint_lane(lane.index)
    assert ckpt["pos"] == 6
    # restore into a FRESH pool (different block layout is fine: the
    # snapshot is logical positions, the table maps them to new blocks)
    pool2 = PagedKVCache(cfg, lanes=2, max_seq=32, block_size=4)
    pool2.assign("other", seq_len=3)        # shift the block layout
    lane2 = pool2.assign("req", seq_len=7)
    pool2.cache = pool2.restore_lane(pool2.cache, lane2.index, ckpt)
    assert pool2.slots[lane2.index].pos == 6
    ckpt2 = pool2.checkpoint_lane(lane2.index)
    assert ckpt2["pos"] == ckpt["pos"]
    for key in ckpt["blocks"]:
        np.testing.assert_array_equal(ckpt2["blocks"][key],
                                      ckpt["blocks"][key])
    for key in ckpt["state"]:
        np.testing.assert_array_equal(ckpt2["state"][key],
                                      ckpt["state"][key])


# ---------------------------------------------------------------------------
# Chaos (slow): kill devices mid-flight; everything recovers, streams exact
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = reduce_cfg(get_config("qwen2-0.5b"), dtype="float32")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _chaos_serve(cfg, params, prompts, max_new, *, kill_after_tokens,
                 watchdog=False, **kw):
    """Serve with a shared epoch; invalidate once `kill_after_tokens`
    tokens are out (0 = mid-prefill).  Returns (streams, lat, srv)."""
    eng = ProgressEngine()
    epoch = NB.MembershipEpoch()
    srv = ServeEngine(cfg, params, eng, batch_slots=3, max_seq=48,
                      epoch=epoch, **kw)
    reqs = [GenRequest(f"r{i}", p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    t0 = time.monotonic()
    while sum(len(r.out_tokens) for r in reqs) < kill_after_tokens \
            and time.monotonic() - t0 < 180:
        eng.progress()
    if watchdog:
        clock = {"t": 0.0}
        wd = StepWatchdog(eng, limit=10.0, clock=lambda: clock["t"],
                          epoch=epoch)
        wd.arm()
        clock["t"] = 11.0
        eng.progress()                       # fires -> epoch invalidated
        assert wd.fired == 1
    else:
        epoch.invalidate(survivors=1, reason="chaos: simulated device loss")
    srv.run_until_idle(timeout=300)
    lat = srv.latency_snapshot()
    streams = [list(r.out_tokens) for r in reqs]
    remeshes = srv.remeshes
    srv.close(timeout=60)
    return streams, lat, remeshes


@pytest.mark.slow
class TestChaosServe:
    def test_kill_mid_decode_slots(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(3)
        prompts = [rng.randint(1, cfg.vocab_size - 1,
                               size=rng.randint(2, 8)).astype(np.int32)
                   for _ in range(6)]
        ref, _ = _serve_streams(cfg, params, prompts, 8, batch_slots=3,
                                max_seq=48)
        got, lat, remeshes = _chaos_serve(cfg, params, prompts, 8,
                                          kill_after_tokens=4)
        assert got == ref                       # replay is bit-exact
        assert lat.completed == 6 and lat.failed == 0
        assert remeshes == 1

    def test_kill_mid_decode_paged_with_kv_migration(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(4)
        prompts = [rng.randint(1, cfg.vocab_size - 1,
                               size=rng.randint(4, 12)).astype(np.int32)
                   for _ in range(8)]
        kw = dict(cache_mode="paged", kv_block_size=4)
        ref, _ = _serve_streams(cfg, params, prompts, 8, batch_slots=3,
                                max_seq=48, **kw)
        got, lat, remeshes = _chaos_serve(cfg, params, prompts, 8,
                                          kill_after_tokens=5, **kw)
        assert got == ref
        assert lat.completed == 8 and lat.failed == 0
        assert remeshes == 1

    def test_kill_mid_prefill_paged(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(5)
        prompts = [rng.randint(1, cfg.vocab_size - 1,
                               size=rng.randint(8, 16)).astype(np.int32)
                   for _ in range(6)]
        kw = dict(cache_mode="paged", kv_block_size=4, prefill_chunk=2)
        ref, _ = _serve_streams(cfg, params, prompts, 6, batch_slots=3,
                                max_seq=48, **kw)
        # kill before ANY token is out: prefills are in flight
        got, lat, remeshes = _chaos_serve(cfg, params, prompts, 6,
                                          kill_after_tokens=0, **kw)
        assert got == ref
        assert lat.completed == 6 and lat.failed == 0
        assert remeshes == 1

    def test_watchdog_fired_restart(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(6)
        prompts = [rng.randint(1, cfg.vocab_size - 1,
                               size=rng.randint(2, 8)).astype(np.int32)
                   for _ in range(4)]
        ref, _ = _serve_streams(cfg, params, prompts, 6, batch_slots=3,
                                max_seq=48)
        got, lat, remeshes = _chaos_serve(cfg, params, prompts, 6,
                                          kill_after_tokens=2,
                                          watchdog=True)
        assert got == ref
        assert lat.completed == 4 and lat.failed == 0
        assert remeshes == 1


@pytest.mark.slow
def test_chaos_kill_mid_gather_sharded():
    """Sharded decode on the user backend: killing a device mid-flight
    fails the persistent allgather retryably; the engine rebuilds on the
    single survivor (unsharded fallback) and streams stay exact."""
    out = run_with_devices("""
        import time
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        from repro.launch.mesh import make_mesh
        from repro.models import registry
        from repro.serve.engine import GenRequest, ServeEngine

        cfg = get_config("qwen2-0.5b").with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2, head_dim=16,
            remat_policy="none", dtype="float32")
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(2)
        prompts = [rng.randint(1, cfg.vocab_size - 1,
                               size=rng.randint(2, 8)).astype(np.int32)
                   for _ in range(4)]

        def serve(epoch=None, kill_at=None):
            eng = ProgressEngine()
            mesh = make_mesh((2,), ("model",))
            srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=32,
                              mesh=mesh, collective_backend="user",
                              epoch=epoch)
            reqs = [GenRequest(f"r{i}", p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                srv.submit(r)
            if kill_at is not None:
                t0 = time.monotonic()
                while sum(len(r.out_tokens) for r in reqs) < kill_at \\
                        and time.monotonic() - t0 < 180:
                    eng.progress()
                epoch.invalidate(survivors=1, reason="chaos")
            srv.run_until_idle(timeout=300)
            lat = srv.latency_snapshot()
            streams = [list(r.out_tokens) for r in reqs]
            rm = srv.remeshes
            srv.close(timeout=60)
            return streams, lat, rm

        ref, _, _ = serve()
        epoch = NB.MembershipEpoch()
        got, lat, remeshes = serve(epoch=epoch, kill_at=3)
        assert got == ref, (got, ref)
        assert lat.completed == 4 and lat.failed == 0
        assert remeshes == 1
        print("SHARDED_CHAOS_OK")
    """, n_devices=2)
    assert "SHARDED_CHAOS_OK" in out


@pytest.mark.slow
def test_train_chaos_trajectory_matches_restart_bitforbit():
    """Kill 2 of 4 devices mid-run: the elastic trainer remeshes and
    retries the failed step's batch, so the loss trajectory from the
    failure on is bit-identical to stopping, checkpointing, and
    restarting on the 2 survivors."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.collectives.nonblocking import MembershipEpoch
        from repro.collectives.overlap import EngineGradReducer
        from repro.data.pipeline import SyntheticLM
        from repro.distributed import elastic
        from repro.models import registry
        from repro.train import optimizer as opt_mod
        from repro.train.train_loop import (Trainer, TrainLoopConfig,
                                            UserCollectiveStep)

        cfg = get_config("smollm-360m").with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2, head_dim=16,
            remat_policy="none")
        STEPS, KILL = 10, 5
        src = SyntheticLM(cfg.vocab_size, 16, 8, seed=3)
        it = iter(src)
        batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
                   for _ in range(STEPS)]

        class ListPipe:
            def __init__(self, bs):
                self.bs = list(bs)
            def next_batch(self):
                return self.bs.pop(0)
            def close(self):
                pass

        ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=2,
                                   total_steps=STEPS)

        def local_grad(params, batch):
            (loss, mets), g = jax.value_and_grad(
                registry.loss_fn, has_aux=True)(params, cfg, batch)
            stacked = jax.tree.map(
                lambda v: v[None].astype(jnp.float32), g)
            return jax.tree.map(lambda v: v[None],
                                dict(mets, loss=loss)), stacked

        def make_grad_fn(mesh_):
            return jax.jit(compat.shard_map(
                local_grad, mesh=mesh_, in_specs=(P(), P("data")),
                out_specs=P("data")))

        @jax.jit
        def apply_fn(params, opt_state, grads, sm):
            params, opt_state, om = opt_mod.apply(ocfg, opt_state,
                                                  params, grads)
            mets = {k: jnp.mean(v) for k, v in sm.items()}
            return params, opt_state, dict(mets, **om)

        def loop_cfg(n, d):
            return TrainLoopConfig(
                total_steps=n, checkpoint_every=10**6,
                checkpoint_dir=f"/tmp/elastic_bitident/{d}",
                log_every=1, resume=False, collective_backend="user")

        def fresh_state():
            params = registry.init_params(cfg, jax.random.PRNGKey(0))
            return params, opt_mod.init(params)

        # --- elastic run: invalidate after step KILL-1 completes ------
        eng = ProgressEngine()
        mesh4 = elastic.remesh(4, prefer_model=1)
        epoch = MembershipEpoch()
        red = EngineGradReducer(mesh4, "data", engine=eng, chunks=2,
                                mean=True, epoch=epoch)
        split = UserCollectiveStep(make_grad_fn(mesh4), apply_fn, red)

        def remesh_fn(exc, params, opt_state):
            new_mesh = elastic.remesh(exc.survivors, prefer_model=1)
            red.remesh(new_mesh, "data")
            params = jax.device_put(params, NamedSharding(new_mesh, P()))
            opt_state = jax.device_put(opt_state,
                                       NamedSharding(new_mesh, P()))
            return (UserCollectiveStep(make_grad_fn(new_mesh), apply_fn,
                                       red), params, opt_state)

        losses, fired = [], []

        def hook(s, m):
            losses.append(m["loss"])
            if s == KILL - 1 and not fired:
                fired.append(s)
                epoch.invalidate(survivors=2, reason="chaos")

        params, opt_state = fresh_state()
        tr = Trainer(None, params, opt_state, ListPipe(batches),
                     loop_cfg(STEPS, "a"), engine=eng, split_step=split,
                     epoch=epoch, remesh_fn=remesh_fn, hooks=[hook])
        tr.run()
        red.close()
        assert tr.recoveries == 1, tr.recoveries
        assert len(losses) == STEPS

        # --- reference: run KILL steps on 4, restart rest on 2 --------
        ref = []
        engA = ProgressEngine()
        redA = EngineGradReducer(mesh4, "data", engine=engA, chunks=2,
                                 mean=True)
        splitA = UserCollectiveStep(make_grad_fn(mesh4), apply_fn, redA)
        params, opt_state = fresh_state()
        trA = Trainer(None, params, opt_state, ListPipe(batches[:KILL]),
                      loop_cfg(KILL, "b1"), engine=engA, split_step=splitA,
                      hooks=[lambda s, m: ref.append(m["loss"])])
        trA.run()
        redA.close()
        mesh2 = elastic.remesh(2, prefer_model=1)
        engB = ProgressEngine()
        redB = EngineGradReducer(mesh2, "data", engine=engB, chunks=2,
                                 mean=True)
        splitB = UserCollectiveStep(make_grad_fn(mesh2), apply_fn, redB)
        p2 = jax.device_put(trA.params, NamedSharding(mesh2, P()))
        o2 = jax.device_put(trA.opt_state, NamedSharding(mesh2, P()))
        trB = Trainer(None, p2, o2, ListPipe(batches[KILL:]),
                      loop_cfg(STEPS - KILL, "b2"), engine=engB,
                      split_step=splitB,
                      hooks=[lambda s, m: ref.append(m["loss"])])
        trB.run()
        redB.close()

        assert len(ref) == STEPS
        for i, (a, b) in enumerate(zip(losses, ref)):
            assert a == b, (i, a, b)       # bit-for-bit, incl. post-kill
        print("TRAIN_BITIDENT_OK")
    """, n_devices=4, timeout=600)
    assert "TRAIN_BITIDENT_OK" in out


# ---------------------------------------------------------------------------
# 2-D mesh FSDP membership: in-flight starts fail once, remesh replans
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_fsdp_invalidate_mid_reduce_scatter_2d_mesh():
    """On a (2,2) data x model mesh, invalidating the epoch while a
    persistent FSDP reduce-scatter is in flight fails that start exactly
    once with a retryable MembershipError; ``remesh`` onto the surviving
    (2,1) mesh replans the handles (fresh schedules for the new mesh,
    same data axis) and the reducer computes exact sums again."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.collectives import nonblocking as NB
        from repro.collectives.overlap import FsdpReducer
        from repro.core import ProgressEngine

        eng = ProgressEngine()
        epoch = NB.MembershipEpoch(n_devices=4)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        spec = NB.CollectiveSpec(backend="user", chunks=2)
        red = FsdpReducer(mesh, "data", engine=eng, spec=spec,
                          epoch=epoch)

        g = jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8)
        r = red.ireduce_scatter([g])
        assert not r.is_complete
        epoch.invalidate(survivors=2, reason="chaos")
        failed_after = red.coll.failed
        assert failed_after >= 1
        try:
            r.wait(timeout=30)
            raise AssertionError("expected MembershipError")
        except NB.MembershipError as e:
            assert e.survivors == 2 and e.version == 1
        # exactly once: a second invalidation does not double-fail
        epoch.invalidate(survivors=2)
        assert red.coll.failed == failed_after

        # survivors' mesh drops the model axis; the data axis (and so
        # the shard widths) survives, handles replan lazily
        mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                     ("data", "model"))
        red.remesh(mesh2)
        assert red.remeshes == 1 and red.axis_size == 2
        out = red.ireduce_scatter([g]).wait(timeout=60)
        ref = np.asarray(g[0] + g[1]).reshape(2, 4)
        assert np.array_equal(np.asarray(out[0]), ref), out
        sh = jnp.arange(2 * 4, dtype=jnp.int32).reshape(2, 4)
        full = red.gather([sh], timeout=60)
        assert np.array_equal(np.asarray(full[0]),
                              np.asarray(sh).reshape(1, 8).repeat(2, 0))
        red.close()
        print("FSDP_RS_EPOCH_OK")
    """, n_devices=4)
    assert "FSDP_RS_EPOCH_OK" in out


@pytest.mark.multidevice
def test_fsdp_invalidate_mid_prefetch_gather_2d_mesh():
    """The other in-flight shape: a continuation-chained prefetch
    all-gather killed mid-start on a (2,2) mesh fails exactly once and
    surfaces the MembershipError from FsdpGather.wait."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.collectives import nonblocking as NB
        from repro.collectives.overlap import FsdpReducer
        from repro.core import ProgressEngine

        eng = ProgressEngine()
        epoch = NB.MembershipEpoch(n_devices=4)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2),
                    ("data", "model"))
        red = FsdpReducer(mesh, "data", engine=eng,
                          spec=NB.CollectiveSpec(backend="user"),
                          epoch=epoch)
        sh = jnp.arange(2 * 4, dtype=jnp.int32).reshape(2, 4)
        gather = red.igather([sh])
        epoch.invalidate(survivors=2, reason="chaos")
        failed_after = red.coll.failed
        assert failed_after >= 1
        try:
            gather.wait(timeout=30)
            raise AssertionError("expected MembershipError")
        except NB.MembershipError as e:
            assert e.survivors == 2
        epoch.invalidate(survivors=2)
        assert red.coll.failed == failed_after     # no double-fail
        red.close()
        print("FSDP_AG_EPOCH_OK")
    """, n_devices=4)
    assert "FSDP_AG_EPOCH_OK" in out
