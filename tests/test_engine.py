"""Progress-engine behaviour tests (paper §3, §4.1–§4.4)."""
import threading
import time

import pytest

from repro.core import (
    DONE, NOPROGRESS, CancelledError, ProgressEngine, Request,
    GeneralizedRequest, TaskQueue, TaskGraph, CompletionWatcher, EventQueue,
)


def make_timer_task(duration, counter):
    """Paper Listing 1.2/1.3: dummy task completing after a preset time."""
    deadline = time.monotonic() + duration

    def poll(thing):
        if time.monotonic() >= deadline:
            counter["n"] -= 1
            return DONE
        return NOPROGRESS
    return poll


class TestBasicProgress:
    def test_tasks_complete_via_progress(self):
        eng = ProgressEngine()
        counter = {"n": 10}
        for _ in range(10):
            eng.async_start(make_timer_task(0.01, counter))
        t0 = time.monotonic()
        while counter["n"] > 0:             # Listing 1.3 wait loop
            eng.progress()
            assert time.monotonic() - t0 < 5.0
        assert counter["n"] == 0
        assert eng.default_stream.pending == 0

    def test_drain_finalize_semantics(self):
        """MPI_Finalize spins progress until all async tasks complete."""
        eng = ProgressEngine()
        counter = {"n": 5}
        for _ in range(5):
            eng.async_start(make_timer_task(0.005, counter))
        eng.drain(timeout=5.0)
        assert counter["n"] == 0

    def test_immediate_done_task(self):
        eng = ProgressEngine()
        hits = []
        eng.async_start(lambda t: (hits.append(1), DONE)[1])
        eng.progress()
        assert hits == [1]
        assert eng.default_stream.pending == 0

    def test_progress_returns_completion_count(self):
        eng = ProgressEngine()
        for _ in range(3):
            eng.async_start(lambda t: DONE)
        assert eng.progress() == 3


class TestAsyncThing:
    def test_get_state(self):
        eng = ProgressEngine()
        seen = []

        def poll(thing):
            seen.append(thing.state)
            return DONE

        eng.async_start(poll, {"x": 42})
        eng.progress()
        assert seen == [{"x": 42}]

    def test_spawn_deferred_no_recursion(self):
        """MPIX_Async_spawn: children run AFTER the current sweep."""
        eng = ProgressEngine()
        order = []

        def child(thing):
            order.append("child")
            return DONE

        def parent(thing):
            order.append("parent")
            thing.spawn(child, None)
            return DONE

        eng.async_start(parent, None)
        eng.progress()                      # sweep 1: parent only
        assert order == ["parent"]
        eng.progress()                      # sweep 2: spawned child
        assert order == ["parent", "child"]

    def test_spawn_to_other_stream(self):
        eng = ProgressEngine()
        s2 = eng.stream("s2")
        done = []

        def child(thing):
            done.append(True)
            return DONE

        def parent(thing):
            thing.spawn(child, None, stream=s2)
            return DONE

        eng.async_start(parent, None)
        eng.progress()
        assert not done                     # child is on s2
        eng.progress(s2)
        assert done == [True]


class TestStreams:
    def test_streams_isolated(self):
        """Progress on one stream must not advance another (§3.2)."""
        eng = ProgressEngine()
        s1, s2 = eng.stream(), eng.stream()
        hits = {"s1": 0, "s2": 0}
        eng.async_start(lambda t: (hits.__setitem__("s1", 1), DONE)[1], None, s1)
        eng.async_start(lambda t: (hits.__setitem__("s2", 1), DONE)[1], None, s2)
        eng.progress(s1)
        assert hits == {"s1": 1, "s2": 0}
        eng.progress(s2)
        assert hits == {"s1": 1, "s2": 1}

    def test_default_stream_is_separate(self):
        eng = ProgressEngine()
        s = eng.stream()
        eng.async_start(lambda t: DONE, None, s)
        eng.progress()                      # default stream: nothing
        assert s.pending == 1
        eng.progress(s)
        assert s.pending == 0

    def test_concurrent_streams_threads(self):
        """Listing 1.5: one stream per thread, no cross contention."""
        eng = ProgressEngine()
        n_threads, n_tasks = 4, 25
        errors = []

        def worker(tid):
            try:
                stream = eng.stream(f"t{tid}")
                counter = {"n": n_tasks}
                for _ in range(n_tasks):
                    eng.async_start(make_timer_task(0.001, counter), None, stream)
                t0 = time.monotonic()
                while counter["n"] > 0:
                    eng.progress(stream)
                    assert time.monotonic() - t0 < 10
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors

    def test_free_stream_with_pending_raises(self):
        eng = ProgressEngine()
        s = eng.stream()
        eng.async_start(lambda t: NOPROGRESS, None, s)
        with pytest.raises(RuntimeError):
            eng.free_stream(s)


class TestSubsystems:
    def test_collated_order_and_short_circuit(self):
        """Listing 1.1: expensive subsystems skipped once progress made."""
        eng = ProgressEngine()
        calls = []
        eng.register_subsystem("datatype", lambda: (calls.append("dt"), True)[1],
                               cheap=True, priority=0)
        eng.register_subsystem("netmod", lambda: (calls.append("net"), False)[1],
                               cheap=False, priority=10)
        eng.progress()
        assert calls == ["dt"]             # netmod skipped: progress was made
        calls.clear()
        eng.progress(skip_expensive_on_progress=False)
        assert calls == ["dt", "net"]

    def test_cheap_subsystems_always_polled(self):
        eng = ProgressEngine()
        calls = []
        eng.register_subsystem("a", lambda: (calls.append("a"), True)[1],
                               cheap=True, priority=0)
        eng.register_subsystem("b", lambda: (calls.append("b"), False)[1],
                               cheap=True, priority=1)
        eng.progress()
        assert calls == ["a", "b"]

    def test_unregister(self):
        eng = ProgressEngine()
        calls = []
        sub = eng.register_subsystem("x", lambda: (calls.append(1), False)[1])
        eng.progress()
        eng.unregister_subsystem(sub)
        eng.progress()
        assert len(calls) == 1


class TestRequests:
    def test_is_complete_no_side_effects(self):
        """MPIX_Request_is_complete never invokes progress (§3.4)."""
        eng = ProgressEngine()
        polled = []
        req = Request()

        def poll(thing):
            polled.append(1)
            req.complete(123)
            return DONE

        eng.async_start(poll, None)
        assert req.is_complete is False
        assert polled == []                 # the query did NOT progress
        eng.progress()
        assert req.is_complete is True
        assert req.value() == 123

    def test_wait_drives_progress(self):
        eng = ProgressEngine()
        req = Request()
        deadline = time.monotonic() + 0.01

        def poll(thing):
            if time.monotonic() >= deadline:
                req.complete("v")
                return DONE
            return NOPROGRESS

        eng.async_start(poll, None)
        assert eng.wait(req, timeout=5.0) == "v"

    def test_generalized_request(self):
        """Listing 1.7: greq completed from inside a poll_fn; MPI_Wait."""
        eng = ProgressEngine()
        freed = []
        greq = GeneralizedRequest(
            query_fn=lambda st: "status-ok",
            free_fn=lambda st: freed.append(st),
            extra_state="es")
        deadline = time.monotonic() + 0.01

        def poll(thing):
            if time.monotonic() >= deadline:
                greq.complete()             # MPI_Grequest_complete
                return DONE
            return NOPROGRESS

        eng.async_start(poll, None)
        assert eng.wait(greq, timeout=5.0) == "status-ok"
        greq.free()
        assert freed == ["es"]

    def test_cancel_completes_grequest(self):
        """Regression: cancel() used to set only the flag — a subsequent
        engine.wait() spun until timeout.  MPI_Cancel + MPI_Wait must
        return: the request completes with a CancelledError failure."""
        eng = ProgressEngine()
        informed = []
        greq = GeneralizedRequest(
            cancel_fn=lambda st, complete: informed.append(complete),
            extra_state="es")
        greq.cancel()
        assert informed == [False]          # callback saw "not yet complete"
        assert greq.cancelled
        assert greq.is_complete             # wait() returns immediately...
        assert greq.failed
        with pytest.raises(CancelledError):  # ...by raising, not spinning
            eng.wait(greq, timeout=1.0)
        # MPI_Grequest_complete racing the cancel must not resurrect it
        greq.complete()
        assert greq.failed

    def test_cancel_after_complete_is_noop(self):
        informed = []
        greq = GeneralizedRequest(
            query_fn=lambda st: "v",
            cancel_fn=lambda st, complete: informed.append(complete))
        greq.complete()
        greq.cancel()
        assert informed == [True]           # callback saw "already complete"
        assert not greq.cancelled           # nothing was cancelled
        assert not greq.failed
        assert greq.value() == "v"


class TestTaskClasses:
    def test_task_queue_in_order(self):
        """Listing 1.4: queue class polls only its head."""
        eng = ProgressEngine()
        q = TaskQueue(eng)
        ready = {"k": 0}
        reqs = [q.submit(lambda i=i: ready["k"] > i) for i in range(5)]
        eng.progress()
        assert all(not r.is_complete for r in reqs)
        ready["k"] = 3
        eng.progress()
        assert [r.is_complete for r in reqs] == [True, True, True, False, False]
        ready["k"] = 5
        eng.progress()
        assert all(r.is_complete for r in reqs)
        assert q.pending == 0

    def test_task_graph_dependencies(self):
        eng = ProgressEngine()
        g = TaskGraph(eng)
        started = []
        r1 = g.add(lambda: True, start_fn=lambda: started.append("a"))
        r2 = g.add(lambda: True, deps=[r1], start_fn=lambda: started.append("b"))
        eng.progress()
        assert r1.is_complete
        eng.progress()
        assert r2.is_complete
        assert started == ["a", "b"]

    def test_task_graph_blocked_tasks_not_polled(self):
        eng = ProgressEngine()
        g = TaskGraph(eng)
        polls = []
        gate = Request()
        g.add(lambda: (polls.append(1), True)[1], deps=[gate])
        eng.progress()
        assert polls == []                  # dependency incomplete: skipped
        gate.complete()
        eng.progress()
        assert polls == [1]


class TestEvents:
    def test_completion_watcher(self):
        """Listing 1.6: callbacks on request completion via query loop."""
        eng = ProgressEngine()
        w = CompletionWatcher(eng)
        fired = []
        reqs = [Request() for _ in range(3)]
        for r in reqs:
            w.watch(r, lambda rr: fired.append(rr.tag or id(rr)))
        eng.progress()
        assert fired == []
        reqs[1].complete()
        eng.progress()
        assert len(fired) == 1
        for r in reqs:
            r.complete()
        eng.progress()
        assert len(fired) == 3

    def test_event_queue_defers_heavy_work(self):
        eng = ProgressEngine()
        evq = EventQueue()
        eng.async_start(lambda t: (evq.emit("ev"), DONE)[1])
        eng.progress()
        assert len(evq) == 1
        assert evq.drain() == ["ev"]
        assert len(evq) == 0


class TestDrainStreamChurn:
    def test_task_freeing_streams_mid_drain(self):
        """A task that frees (and creates) OTHER streams while drain
        sweeps must not corrupt the stream list or wedge the drain."""
        eng = ProgressEngine()
        victims = [eng.stream(f"victim{i}") for i in range(4)]
        work = eng.stream("work")
        state = {"n": 0}

        def poll(thing):
            state["n"] += 1
            if victims:
                eng.free_stream(victims.pop())   # churn during the sweep
                eng.stream(f"new{state['n']}")   # and grow the list too
                return NOPROGRESS
            return DONE

        eng.async_start(poll, None, work)
        eng.drain(timeout=5.0)                   # must terminate cleanly
        assert work.pending == 0
        assert state["n"] >= 5

    def test_concurrent_free_during_drain(self):
        """Regression: drain(stream=None) iterated the live stream list;
        a concurrent free_stream blew it up with 'list changed size
        during iteration'.  The list is snapshotted now."""
        eng = ProgressEngine()
        deadline = time.monotonic() + 0.2

        def slow(thing):
            return DONE if time.monotonic() >= deadline else NOPROGRESS

        eng.async_start(slow, None, eng.stream("busy"))
        stop = threading.Event()
        errors = []

        def churn():
            while not stop.is_set():
                s = eng.stream("churn")
                try:
                    eng.free_stream(s)
                except BaseException as exc:  # noqa: BLE001
                    errors.append(exc)

        t = threading.Thread(target=churn, daemon=True)
        t.start()
        try:
            eng.drain(timeout=10.0)              # raced the churn thread
        finally:
            stop.set()
            t.join(timeout=5.0)
        assert errors == []
