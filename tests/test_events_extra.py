"""Event-path coverage: watcher re-entrancy, bounded drains, failure
propagation through task graphs, and the O(1) head-only queue claim."""
import pytest

from repro.core import (
    DONE, NOPROGRESS, CompletionWatcher, EventQueue, ProgressEngine,
    Request, TaskGraph, TaskQueue,
)


class TestCompletionWatcherReentrancy:
    def test_callback_registers_new_watch(self):
        """A completion callback may register a follow-up watch on the
        SAME watcher from inside the callback (the continuation pattern:
        each completion schedules the next stage)."""
        eng = ProgressEngine()
        w = CompletionWatcher(eng)
        fired = []
        first, second = Request(tag="first"), Request(tag="second")

        def on_first(req):
            fired.append(req.tag)
            w.watch(second, lambda r: fired.append(r.tag))  # re-entrant

        w.watch(first, on_first)
        first.complete()
        eng.progress()
        assert fired == ["first"]
        assert w.pending == 1                     # the re-entrant watch
        second.complete()
        eng.progress()
        assert fired == ["first", "second"]
        assert w.pending == 0
        # watcher's internal poll task must have retired cleanly
        eng.progress()
        assert eng.default_stream.pending == 0

    def test_callback_chain_three_deep(self):
        eng = ProgressEngine()
        w = CompletionWatcher(eng)
        order = []
        reqs = [Request(tag=f"r{i}") for i in range(3)]

        def chained(i):
            def cb(req):
                order.append(req.tag)
                if i + 1 < len(reqs):
                    w.watch(reqs[i + 1], chained(i + 1))
                    reqs[i + 1].complete()
            return cb

        w.watch(reqs[0], chained(0))
        reqs[0].complete()
        for _ in range(4):
            eng.progress()
        assert order == ["r0", "r1", "r2"]


class TestEventQueueBounds:
    def test_drain_max_events_bounds(self):
        evq = EventQueue()
        for i in range(10):
            evq.emit(i)
        assert evq.drain(max_events=3) == [0, 1, 2]
        assert len(evq) == 7
        assert evq.drain(max_events=0) == []      # zero means take nothing
        assert evq.drain(max_events=100) == list(range(3, 10))
        assert evq.drain(max_events=5) == []      # empty queue
        assert len(evq) == 0

    def test_drain_unbounded_default(self):
        evq = EventQueue()
        for i in range(4):
            evq.emit(i)
        assert evq.drain() == [0, 1, 2, 3]


class TestTaskGraphFailurePropagation:
    def test_dep_fail_fails_dependent_without_starting(self):
        eng = ProgressEngine()
        g = TaskGraph(eng)
        started = []
        dep = Request()
        r = g.add(lambda: True, deps=[dep],
                  start_fn=lambda: started.append("x"))
        eng.progress()
        assert not r.is_complete
        boom = ValueError("upstream exploded")
        dep.fail(boom)
        eng.progress()
        assert r.is_complete and r.failed
        assert started == []                      # never launched
        with pytest.raises(ValueError, match="upstream exploded"):
            r.value()
        assert r.exception is boom                # original, not wrapped
        assert g.pending == 0

    def test_failure_propagates_transitively(self):
        """a -> b -> c: failing a's dep fails b, which fails c."""
        eng = ProgressEngine()
        g = TaskGraph(eng)
        gate = Request()
        ra = g.add(lambda: True, deps=[gate])
        rb = g.add(lambda: True, deps=[ra])
        rc = g.add(lambda: True, deps=[rb])
        eng.progress()
        assert not (ra.is_complete or rb.is_complete or rc.is_complete)
        gate.fail(RuntimeError("root cause"))
        for _ in range(3):                        # one hop per sweep
            eng.progress()
        assert ra.failed and rb.failed and rc.failed
        with pytest.raises(RuntimeError, match="root cause"):
            rc.value()

    def test_sibling_unaffected_by_failure(self):
        eng = ProgressEngine()
        g = TaskGraph(eng)
        bad_dep, good_dep = Request(), Request()
        r_bad = g.add(lambda: True, deps=[bad_dep])
        r_good = g.add(lambda: True, deps=[good_dep],
                       on_complete=lambda: "ok")
        bad_dep.fail(RuntimeError("nope"))
        good_dep.complete()
        eng.progress()
        eng.progress()
        assert r_bad.failed
        assert r_good.is_complete and r_good.value() == "ok"


class TestTaskQueueHeadOnlyPolling:
    def test_only_head_ready_fn_polled(self):
        """The Fig-10 claim: progress cost is O(1) because only the queue
        HEAD's ready_fn runs per sweep — tail tasks are never polled."""
        eng = ProgressEngine()
        q = TaskQueue(eng)
        counts = [0] * 5
        ready = {"upto": 0}

        def mk(i):
            def ready_fn():
                counts[i] += 1
                return i < ready["upto"]
            return ready_fn

        reqs = [q.submit(mk(i)) for i in range(5)]
        for _ in range(4):
            eng.progress()
        assert counts[0] == 4                     # head polled each sweep
        assert counts[1:] == [0, 0, 0, 0]         # tail untouched: O(1)
        # release the first three: one sweep pops them in order, then
        # polls the new head exactly once
        ready["upto"] = 3
        eng.progress()
        assert [r.is_complete for r in reqs] == [True] * 3 + [False] * 2
        assert counts[3] == 1 and counts[4] == 0
        ready["upto"] = 5
        eng.progress()
        assert all(r.is_complete for r in reqs)
        assert counts[4] >= 1
        assert q.pending == 0
