"""Multi-threaded progress executor + wait-set tests (paper §4.4/§4.5).

The assertions lean on repro.core.stats: the §4.4 claim is not just
"N workers make progress" but "N workers on disjoint streams never
contend" — Stream.contention counts exactly those lock collisions.
"""
import threading
import time

import pytest

from repro.core import (
    DEFERRED, DONE, NOPROGRESS, CompletionCounter, ContinuationQueue,
    ProgressEngine, ProgressExecutor, Request, stats,
)


def timed_task(duration, req=None, value=None):
    """Dummy task (Listing 1.3) completing after ``duration`` seconds."""
    deadline = time.monotonic() + duration

    def poll(thing):
        if time.monotonic() >= deadline:
            if req is not None:
                req.complete(value)
            return DONE
        return NOPROGRESS
    return poll


def wait_until(pred, timeout=10.0, what="condition"):
    t0 = time.monotonic()
    while not pred():
        time.sleep(0.0005)
        if time.monotonic() - t0 > timeout:
            raise TimeoutError(what)


class TestExecutorBasics:
    def test_two_workers_two_disjoint_streams_progress_concurrently(self):
        """The acceptance scenario: each stream's task completes only
        after the OTHER stream has been polled — possible only if two
        workers progress the streams concurrently — and disjoint streams
        show zero lock contention (Fig 11, not Fig 9)."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, steal=False)
        s1, s2 = ex.stream("left"), ex.stream("right")
        polled = {"left": 0, "right": 0}
        done = {"left": False, "right": False}

        def make(mine, other):
            def poll(thing):
                polled[mine] += 1
                if polled[other] > 0:          # requires concurrent polling
                    done[mine] = True
                    return DONE
                return NOPROGRESS
            return poll

        eng.async_start(make("left", "right"), None, s1)
        eng.async_start(make("right", "left"), None, s2)
        with ex:
            wait_until(lambda: done["left"] and done["right"], 10,
                       "cross-stream completion")
        st = stats.collect(eng, ex)
        assert st.stream("left").contention == 0
        assert st.stream("right").contention == 0
        assert st.stream("left").completions == 1
        assert st.stream("right").completions == 1

    def test_tasks_run_on_worker_threads_not_caller(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, steal=False)
        s1, s2 = ex.stream(), ex.stream()
        idents = {s1.name: set(), s2.name: set()}
        stop = {"v": False}

        def make(stream):
            def poll(thing):
                if stop["v"]:
                    return DONE
                idents[stream.name].add(threading.get_ident())
                return NOPROGRESS
            return poll

        eng.async_start(make(s1), None, s1)
        eng.async_start(make(s2), None, s2)
        ex.start()
        wait_until(lambda: idents[s1.name] and idents[s2.name], 10)
        ids1, ids2 = set(idents[s1.name]), set(idents[s2.name])
        stop["v"] = True
        ex.shutdown(drain=True, timeout=5)
        assert threading.get_ident() not in ids1 | ids2
        # steal=False: one dedicated worker per stream, and they differ
        assert len(ids1) == 1 and len(ids2) == 1
        assert ids1 != ids2

    def test_drain_leaves_zero_pending(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        streams = [ex.stream(f"d{i}") for i in range(4)]
        for s in streams:
            for _ in range(5):
                eng.async_start(timed_task(0.01), None, s)
        ex.start()
        ex.drain(timeout=10)
        assert all(s.pending == 0 for s in streams)
        ex.shutdown(drain=True, timeout=5)
        assert not ex.running

    def test_shutdown_absorbs_pending_cross_thread_incoming(self):
        """async_start lands tasks in the stream's cross-thread _incoming
        buffer; shutdown(drain=True) must absorb and complete them even
        when they were enqueued a moment before shutdown."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s = ex.stream("late")
        ex.start()
        reqs = [Request() for _ in range(20)]
        for r in reqs:
            eng.async_start(timed_task(0.002, req=r), None, s)  # -> _incoming
        ex.shutdown(drain=True, timeout=10)
        assert s.pending == 0
        assert all(r.is_complete for r in reqs)

    def test_shutdown_without_drain_leaves_tasks(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1, steal=False)
        s = ex.stream()
        ex.start()
        ex.shutdown(drain=False)
        eng.async_start(lambda t: NOPROGRESS, None, s)
        assert s.pending == 1

    def test_free_stream_raises_on_pending_work(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1)
        s = ex.stream("busy")
        eng.async_start(lambda t: NOPROGRESS, None, s)
        with pytest.raises(RuntimeError, match="pending"):
            eng.free_stream(s)

    def test_drain_inline_when_not_running(self):
        """drain works before start(): the caller progresses inline."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s = ex.stream()
        for _ in range(3):
            eng.async_start(timed_task(0.002), None, s)
        ex.drain(timeout=10)
        assert s.pending == 0


class TestWorkStealing:
    def test_idle_worker_steals_from_loaded_worker(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, steal=True, steal_after=2)
        # both streams on worker 0; worker 1 starts idle and must steal
        s1, s2 = eng.stream("a"), eng.stream("b")
        ex.adopt(s1, worker=0)
        ex.adopt(s2, worker=0)
        for s in (s1, s2):
            for _ in range(3):
                eng.async_start(timed_task(0.05), None, s)
        with ex:
            wait_until(lambda: sum(w.steals for w in ex.worker_stats()) > 0,
                       10, "steal")
            counts = [len(w.streams) for w in ex.worker_stats()]
            assert counts == [1, 1]
            ex.drain(timeout=10)
        assert s1.pending == 0 and s2.pending == 0

    def test_steal_preserves_single_owner_progress(self):
        """After a steal, the stream still completes everything exactly
        once (the serial-context invariant holds through the handoff)."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=4, steal=True, steal_after=1)
        streams = [eng.stream(f"s{i}") for i in range(8)]
        for s in streams:
            ex.adopt(s, worker=0)               # all start on one worker
        completions = {"n": 0}
        lock = threading.Lock()
        total = 0
        for s in streams:
            for _ in range(10):
                total += 1
                deadline = time.monotonic() + 0.02

                def poll(thing, deadline=deadline):
                    if time.monotonic() >= deadline:
                        with lock:
                            completions["n"] += 1
                        return DONE
                    return NOPROGRESS

                eng.async_start(poll, None, s)
        with ex:
            ex.drain(timeout=15)
        assert completions["n"] == total
        assert sum(s.completions for s in streams) == total


class TestWaitSets:
    def test_wait_any_returns_first_completed(self):
        """Acceptance: wait_any returns the first-completed request."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        s = ex.stream()
        reqs = [Request(tag=f"r{i}") for i in range(4)]
        durations = [0.05, 0.004, 0.05, 0.05]      # r1 finishes first
        for r, d in zip(reqs, durations):
            eng.async_start(timed_task(d, req=r, value=r.tag), None, s)
        with ex:
            idx, req = eng.wait_any(reqs, timeout=10)
            assert idx == 1 and req is reqs[1]
            assert req.value() == "r1"
            ex.drain(timeout=10)

    def test_wait_any_caller_driven(self):
        """wait_any drives progress itself when no executor is attached."""
        eng = ProgressEngine()
        reqs = [Request(), Request()]
        eng.async_start(timed_task(0.05, req=reqs[0]))
        eng.async_start(timed_task(0.002, req=reqs[1]))
        idx, _ = eng.wait_any(reqs, timeout=10)
        assert idx == 1

    def test_wait_any_prefers_lowest_index_when_already_complete(self):
        eng = ProgressEngine()
        reqs = [Request(), Request(), Request()]
        reqs[2].complete()
        reqs[1].complete()
        idx, req = eng.wait_any(reqs, timeout=1)
        assert idx == 1                           # deterministic tiebreak

    def test_wait_some_returns_completion_order(self):
        eng = ProgressEngine()
        reqs = [Request(tag=f"r{i}") for i in range(4)]
        durations = [0.03, 0.002, 0.02, 0.01]      # order: 1, 3, 2, 0
        for r, d in zip(reqs, durations):
            eng.async_start(timed_task(d, req=r), None)
        idx = eng.wait_some(reqs, min_count=3, timeout=10)
        assert idx == [1, 3, 2]
        # a fresh call observes already-complete requests in index order
        # (deterministic, like MPI_Waitsome), stragglers in arrival order
        idx_all = eng.wait_some(reqs, min_count=4, timeout=10)
        assert idx_all == [1, 2, 3, 0]

    def test_wait_on_unadopted_stream_does_not_deadlock(self):
        """A running executor must not starve waits on streams it does
        NOT own: the waiter progresses those inline instead of yielding."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1)
        ex.stream("owned")
        req = Request()
        eng.async_start(timed_task(0.01, req=req))   # default: unadopted
        with ex:
            assert eng.wait(req, timeout=10) is None
        assert eng.default_stream.pending == 0

    def test_wait_some_min_count_validation(self):
        eng = ProgressEngine()
        with pytest.raises(ValueError):
            eng.wait_some([Request()], min_count=2)
        with pytest.raises(ValueError):
            eng.wait_any([])

    def test_completion_counter(self):
        eng = ProgressEngine()
        reqs = [Request() for _ in range(5)]
        cc = CompletionCounter(reqs[:3])
        for r in reqs[3:]:
            cc.add(r)
        assert cc.total == 5 and cc.remaining == 5 and not cc.is_complete
        for r in reqs[:4]:
            eng.async_start(timed_task(0.002, req=r))
        eng.wait_all(reqs[:4], timeout=10)
        assert cc.completed == 4 and cc.remaining == 1
        reqs[4].fail(RuntimeError("boom"))
        assert cc.is_complete                      # failed still completes
        assert cc.failed == [reqs[4]]

    def test_completion_counter_as_request_waitable(self):
        eng = ProgressEngine()
        reqs = [Request() for _ in range(3)]
        cc = CompletionCounter(reqs)
        for r in reqs:
            eng.async_start(timed_task(0.005, req=r))
        eng.wait(cc.as_request(), timeout=10)
        assert cc.remaining == 0


class TestFaultIsolation:
    def test_subsystem_error_isolated_and_recorded(self):
        """A raising subsystem is unregistered, recorded, and does not
        take down global progress (the Listing 1.1 contract)."""
        eng = ProgressEngine()
        good = []
        eng.register_subsystem("bad", lambda: 1 / 0, priority=0)
        eng.register_subsystem("good", lambda: (good.append(1), True)[1],
                               priority=1)
        made = eng.progress()                      # must not raise
        assert good == [1] and made >= 1
        assert len(eng.subsystem_errors) == 1
        assert eng.subsystem_errors[0][0] == "bad"
        assert isinstance(eng.subsystem_errors[0][1], ZeroDivisionError)
        eng.progress()
        assert len(eng.subsystem_errors) == 1      # bad was unregistered
        st = stats.collect(eng)
        assert st.subsystem("good").polls == 2

    def test_subsystem_error_strict_reraises(self):
        eng = ProgressEngine()
        eng.register_subsystem("bad", lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            eng.progress(strict=True)
        # still isolated: subsequent non-strict progress is clean
        assert eng.progress() == 0

    def test_broken_task_dropped_not_respun(self):
        """A poll_fn that raises is removed from the stream (else every
        subsequent sweep re-raises forever)."""
        eng = ProgressEngine()
        survivor = {"polls": 0}

        def bad(thing):
            raise RuntimeError("task bug")

        def good(thing):
            survivor["polls"] += 1
            return DONE if survivor["polls"] >= 2 else NOPROGRESS

        eng.async_start(bad)
        eng.async_start(good)
        with pytest.raises(RuntimeError, match="task bug"):
            eng.progress()
        assert len(eng.default_stream.task_errors) == 1
        eng.progress()
        eng.progress()
        assert survivor["polls"] == 2              # good task survived
        assert eng.default_stream.pending == 0

    def test_executor_worker_survives_broken_task(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1, steal=False)
        s = ex.stream()
        req = Request()
        eng.async_start(lambda t: 1 / 0, None, s)
        eng.async_start(timed_task(0.005, req=req), None, s)
        with ex:
            wait_until(lambda: req.is_complete, 10, "survivor completion")
            assert len(ex.errors) == 1
            ex.drain(timeout=5)


class TestSubsystemCriticalSection:
    def test_hooks_never_polled_concurrently(self):
        """Subsystem hooks need no thread safety: even with many threads
        calling engine.progress, hooks run inside a try-lock critical
        section (MPICH's progress lock), one thread at a time."""
        eng = ProgressEngine()
        overlaps = []
        gate = threading.Lock()

        def hook():
            if not gate.acquire(blocking=False):
                overlaps.append(1)          # second thread inside the hook
                return False
            try:
                time.sleep(0.0002)
                return False
            finally:
                gate.release()

        eng.register_subsystem("fragile", hook)
        stop = time.monotonic() + 0.1

        def spin():
            while time.monotonic() < stop:
                eng.progress()

        threads = [threading.Thread(target=spin) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert overlaps == []

    def test_executor_plus_caller_progress_single_fill(self):
        """The trainer hang regression: a subsystem pulling from a shared
        generator (PrefetchPipeline pattern) must survive a caller spinning
        engine.progress while an executor worker polls the hooks."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)
        ex.adopt(eng.default_stream)

        def gen():
            i = 0
            while True:
                time.sleep(0.0002)          # widen the race window
                i += 1
                yield i

        source = gen()
        got = []
        eng.register_subsystem("puller", lambda: (got.append(next(source)),
                                                  True)[1])
        ex.start()
        t0 = time.monotonic()
        while len(got) < 50:
            eng.progress()                  # caller races the worker
            assert time.monotonic() - t0 < 10
        ex.shutdown(drain=True, timeout=5)
        assert eng.subsystem_errors == []   # no 'generator already executing'
        assert got[:50] == sorted(got[:50])


class TestStreamChurnStress:
    def test_register_unregister_streams_under_load(self):
        """Stress: streams are created, loaded, drained, and freed WHILE
        workers poll, steal, and fire continuations.  Invariants: every
        task's continuation fires exactly once (none lost, none doubled)
        and shutdown is clean."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=3, steal=True, steal_after=2,
                              continuation_max_drain=16)
        q = ContinuationQueue(eng, ex.stream("stress-detect"),
                              policy=DEFERRED, name="stress")
        ex.adopt_queue(q)
        fired: dict[tuple, int] = {}
        flock = threading.Lock()
        total = 0
        waves, tasks_per_wave = 12, 8
        with ex:
            live: list = []
            for wave in range(waves):
                s = ex.stream(f"churn{wave}")
                live.append(s)
                for t in range(tasks_per_wave):
                    key = (wave, t)
                    fired[key] = 0
                    r = Request()

                    def cb(rr, key=key):
                        with flock:
                            fired[key] += 1

                    q.attach(r, cb)
                    eng.async_start(
                        timed_task(0.0005 * (t % 3), req=r), None, s)
                    total += 1
                # churn: retire every already-drained older stream while
                # the workers are mid-flight on the rest
                for old in list(live):
                    if old is not s and old.pending == 0:
                        ex.release(old)
                        eng.free_stream(old)
                        live.remove(old)
                time.sleep(0.001)
            ex.drain(timeout=30)
        assert not ex.running                     # clean shutdown
        assert ex.errors == []
        assert sum(fired.values()) == total       # no lost tasks
        assert all(v == 1 for v in fired.values())  # no double-execution
        assert q.executed == total
        assert q.pending == 0 and q.ready == 0
        # every surviving stream fully drained
        assert all(s.pending == 0 for s in live)

    def test_adoption_churn_with_outside_waiters(self):
        """Streams hop between executor ownership and caller-driven
        progress (release → engine.wait → re-adopt) without losing
        completions or deadlocking."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, steal=False)
        s = ex.stream("hop")
        with ex:
            for round_ in range(6):
                r = Request()
                eng.async_start(timed_task(0.002, req=r, value=round_),
                                None, s)
                if round_ % 2 == 0:
                    assert eng.wait(r, timeout=10) == round_  # worker-owned
                else:
                    ex.release(s)
                    # caller-driven: wait progresses the unadopted stream
                    assert eng.wait(r, stream=s, timeout=10) == round_
                    ex.adopt(s)
            ex.drain(timeout=10)
        assert s.pending == 0


class TestStats:
    def test_idle_spins_and_polls_counted(self):
        eng = ProgressEngine()
        eng.async_start(timed_task(10.0))          # never completes here
        for _ in range(5):
            eng.progress()
        st = stats.collect(eng)
        ds = st.stream("default")
        assert ds.polls == 5
        assert ds.idle_spins == 5
        assert ds.completions == 0 and ds.pending == 1

    def test_format_stats_runs(self):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=1)
        ex.stream("x")
        eng.register_subsystem("sub", lambda: False)
        eng.progress()
        text = stats.format_stats(stats.collect(eng, ex))
        assert "default" in text and "sub" in text and "w0" in text
