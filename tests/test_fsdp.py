"""ZeRO-style FSDP on user-space collectives.

Three correctness tiers:

* schedule level — recursive-halving reduce-scatter / recursive-doubling
  all-gather match the native tiled ops on power-of-two axes, and
  ``resolve_rs_ag_algorithm`` falls back to ring (with a warning) for
  non-power-of-two sizes and for algorithm names with no rs/ag phase;
* engine level — the persistent user reduce-scatter / all-gather handles
  return exactly the ring results for ``halving_doubling`` and for
  chunk-stacked fusion (integer payloads make the comparison exact at
  any axis size);
* step level — the user-backend FSDP training step produces a loss
  trajectory BIT-identical to the native in-program
  ``all_gather``/``psum_scatter`` step over 20 steps on (1,1), (2,1) and
  (2,2) meshes: both backends run THE SAME jitted grad/apply programs
  (only the byte movement differs), and the two-term data-axis sums are
  order-invariant, so there is no tolerance to hide behind.
"""
import numpy as np
import pytest

from repro.collectives import schedules as S
from tests._multidevice import run_with_devices


# ---------------------------------------------------------------------------
# Schedule level: halving/doubling rs + ag vs native, and the resolver
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("n_devices", [2, 4])
def test_hd_rs_ag_schedules_match_native(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        for D in (n * 3, n * 16):               # odd and power-of-two /P
            x = jax.random.normal(jax.random.PRNGKey(D), (n * 2, 2, D))
            rs_u = jax.jit(compat.shard_map(
                lambda v: S.recursive_halving_reduce_scatter(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            rs_n = jax.jit(compat.shard_map(
                lambda v: jax.lax.psum_scatter(v, "x",
                                               scatter_dimension=v.ndim - 1,
                                               tiled=True),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            np.testing.assert_allclose(np.asarray(rs_u), np.asarray(rs_n),
                                       atol=1e-5, err_msg=f"rs D={{D}}")
            s = jax.random.normal(jax.random.PRNGKey(D + 1), (n * 2, 2, D))
            ag_u = jax.jit(compat.shard_map(
                lambda v: S.recursive_doubling_all_gather(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(s)
            ag_n = jax.jit(compat.shard_map(
                lambda v: jax.lax.all_gather(v, "x", axis=v.ndim - 1,
                                             tiled=True),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(s)
            assert np.array_equal(np.asarray(ag_u), np.asarray(ag_n)), \\
                f"ag D={{D}}"
        print("HD_RS_AG_OK")
    """, n_devices=n_devices)
    assert "HD_RS_AG_OK" in out


class TestRsAgResolver:
    def test_pow2_passthrough(self):
        assert S.resolve_rs_ag_algorithm("halving_doubling", 4) \
            == "halving_doubling"
        assert S.resolve_rs_ag_algorithm("ring", 3) == "ring"

    def test_non_pow2_falls_back_to_ring(self):
        with pytest.warns(RuntimeWarning, match="power-of-two"):
            assert S.resolve_rs_ag_algorithm("halving_doubling", 3) == "ring"

    def test_no_rs_phase_falls_back_to_ring(self):
        # bidir/recursive_doubling are allreduce-shaped end to end: no
        # standalone reduce-scatter phase to decompose
        with pytest.warns(RuntimeWarning, match="no reduce_scatter"):
            assert S.resolve_rs_ag_algorithm("bidir", 4) == "ring"
        with pytest.warns(RuntimeWarning, match="no allgather"):
            assert S.resolve_rs_ag_algorithm("recursive_doubling", 4,
                                             op="allgather") == "ring"

    def test_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown"):
            S.resolve_rs_ag_algorithm("bogus", 4)


# ---------------------------------------------------------------------------
# Engine level: persistent user rs/ag — hd and stacked fusion, exact
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("n_devices", [2, 4])
def test_user_rs_ag_hd_and_stacked_exact(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.collectives.nonblocking import (CollectiveSpec,
                                                   default_collectives)
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        coll = default_collectives()
        # integer payloads: float summation order varies by algorithm,
        # int sums do not, so every variant must agree to the bit
        x = jnp.arange(n * 2 * 4 * n, dtype=jnp.int32).reshape(n * 2, 4 * n)
        rs_ref = jax.jit(compat.shard_map(
            lambda v: jax.lax.psum_scatter(v, "x", scatter_dimension=1,
                                           tiled=True),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        g = jnp.arange(n * 2 * 8, dtype=jnp.int32).reshape(n * 2, 8)
        ag_ref = jax.jit(compat.shard_map(
            lambda v: jax.lax.all_gather(v, "x", axis=1, tiled=True),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(g)
        for alg in ("ring", "halving_doubling"):
            for chunks in (1, 2):
                spec = CollectiveSpec(backend="user", algorithm=alg,
                                      chunks=chunks)
                rs = coll.ireduce_scatter(x, mesh, "x",
                                          spec=spec).wait(timeout=120)
                assert np.array_equal(np.asarray(rs),
                                      np.asarray(rs_ref)), (alg, chunks)
                ag = coll.iallgather(g, mesh, "x",
                                     spec=spec).wait(timeout=120)
                assert np.array_equal(np.asarray(ag),
                                      np.asarray(ag_ref)), (alg, chunks)
        print("USER_RS_AG_EXACT_OK")
    """, n_devices=n_devices)
    assert "USER_RS_AG_EXACT_OK" in out


# ---------------------------------------------------------------------------
# Layout: shard/unshard round trip
# ---------------------------------------------------------------------------

def test_fsdp_layout_roundtrip():
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.collectives.overlap import FsdpLayout

    params = {"a": jnp.arange(7, dtype=jnp.float32),
              "b": jnp.ones((3, 5), jnp.float32) * 2,
              "c": jnp.arange(4, dtype=jnp.int32)}
    mesh = compat.make_mesh((1,), ("data",))
    layout = FsdpLayout(params, 1, 1 << 20)
    # int and float leaves land in different dtype buckets
    assert layout.num_buckets == 2
    shards = layout.shard_params(params, mesh, "data")
    back = layout.unshard_params(shards)
    for k in params:
        assert np.array_equal(np.asarray(back[k]), np.asarray(params[k])), k
    # the traceable flatten matches the host-side shard layout
    leaves = jax.tree.leaves(params)
    for b in range(layout.num_buckets):
        flat = layout.flatten_bucket(leaves, b)
        assert np.array_equal(np.asarray(flat),
                              np.asarray(shards[b][0])), b


# ---------------------------------------------------------------------------
# Step level: 20-step loss trajectory, user == native to the bit
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("n_devices,data,model",
                         [(1, 1, 1), (2, 2, 1), (4, 2, 2)])
def test_fsdp_loss_bitwise_user_vs_native(n_devices, data, model):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.collectives.nonblocking import CollectiveSpec
        from repro.collectives.overlap import FsdpLayout, FsdpReducer
        from repro.core import ProgressEngine
        from repro.data.pipeline import SyntheticLM
        from repro.launch.train import build_fsdp_programs
        from repro.models import registry
        from repro.train import optimizer as opt_mod
        from repro.train.train_loop import (FsdpStep, Trainer,
                                            TrainLoopConfig)

        dd, mm = {data}, {model}
        cfg = get_config('smollm-360m').with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2, head_dim=16, remat_policy='none')
        STEPS = 20
        ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=2,
                                   total_steps=STEPS)
        mesh = Mesh(np.array(jax.devices()[:dd * mm]).reshape(dd, mm),
                    ('data', 'model'))
        src = SyntheticLM(cfg.vocab_size, 16, 4, seed=11)
        it = iter(src)
        batches = [{{k: jnp.asarray(v) for k, v in next(it).items()}}
                   for _ in range(STEPS)]

        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        layout = FsdpLayout(params, dd, 1 << 22)
        sharding = NamedSharding(mesh, P('data'))

        def fresh_state():
            shards = layout.shard_params(params, mesh, 'data')
            return shards, opt_mod.AdamWState(
                jnp.zeros((), jnp.int32),
                [jax.device_put(jnp.zeros_like(s), sharding)
                 for s in shards],
                [jax.device_put(jnp.zeros_like(s), sharding)
                 for s in shards])

        grad_fn, apply_fn, ag_fn, rs_fn = build_fsdp_programs(
            cfg, ocfg, mesh, layout, axis='data')

        sh, st = fresh_state()
        native = []
        for b in batches:
            flats = ag_fn(sh)
            smets, flat_grads = grad_fn(flats, b)
            gshards = rs_fn(flat_grads)
            sh, st, mets = apply_fn(sh, st, gshards, smets)
            native.append(np.float32(mets['loss']))

        class ListPipe:
            def __init__(self, bs):
                self.bs = list(bs)
            def next_batch(self):
                return self.bs.pop(0)
            def close(self):
                pass

        eng = ProgressEngine()
        spec = CollectiveSpec(backend='user', chunks=2)
        reducer = FsdpReducer(mesh, 'data', engine=eng, spec=spec,
                              bucket_bytes=1 << 22)
        split = FsdpStep(grad_fn, apply_fn, reducer, spec=spec)
        losses = {{}}
        sh_u, st_u = fresh_state()
        tr = Trainer(None, sh_u, st_u, ListPipe(batches),
                     TrainLoopConfig(
                         total_steps=STEPS, checkpoint_every=10**6,
                         checkpoint_dir='/tmp/fsdp_bit_{data}x{model}',
                         log_every=1, resume=False,
                         collective_spec=spec),
                     engine=eng, split_step=split,
                     hooks=[lambda s, m: losses.__setitem__(
                         s, np.float32(m['loss']))])
        tr.run()
        overlap, gathers = reducer.prefetch_overlap, reducer.gathers
        reducer.close()

        user = [losses[s] for s in range(STEPS)]
        bad = [(s, float(a), float(b))
               for s, (a, b) in enumerate(zip(native, user)) if a != b]
        assert not bad, f'loss trajectories diverged: {{bad[:4]}}'
        if dd > 1:
            assert gathers > 0
            assert overlap > 0.0, overlap
        print(f'FSDP_BITWISE_OK overlap={{overlap:.3f}}')
    """, n_devices=n_devices)
    assert "FSDP_BITWISE_OK" in out
