"""Import smoke test: every ``repro.*`` module must import cleanly.

Collection errors elsewhere in the suite (a missing optional dependency,
a syntax error in a rarely-run module) surface here as one clear,
per-module failure instead of a pytest collection abort.
"""
import importlib
import pkgutil

import pytest

import repro


def _all_modules() -> list[str]:
    names = ["repro"]
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(mod.name)
    return sorted(names)


@pytest.mark.parametrize("name", _all_modules())
def test_module_imports(name):
    importlib.import_module(name)


def test_core_public_api_complete():
    """Everything in repro.core.__all__ actually resolves."""
    import repro.core as core
    for sym in core.__all__:
        assert getattr(core, sym, None) is not None, sym
