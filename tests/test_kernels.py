"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.decode_attention import flash_decode as fd_kernel
from repro.kernels.rmsnorm import rmsnorm_bwd, rmsnorm_fwd
from repro.kernels.ssd_scan import ssd_chunk as ssd_kernel

KEY = jax.random.PRNGKey(42)


def tols(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,H,KVH,hd", [
        (1, 128, 128, 2, 2, 64),
        (2, 256, 256, 4, 2, 64),
        (1, 256, 512, 6, 3, 64),     # GQA, Sk > Sq
        (2, 128, 128, 8, 2, 128),
        (1, 384, 384, 3, 1, 64),     # MQA, odd head count
    ])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, B, Sq, Sk, H, KVH, hd, causal, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, Sk, KVH, hd), dtype)
        v = jax.random.normal(ks[2], (B, Sk, KVH, hd), dtype)
        out = fa_kernel(q, k, v, causal=causal, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expected, np.float32),
            **tols(dtype))

    def test_block_shape_independence(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 512, 4, 64))
        k = jax.random.normal(ks[1], (1, 512, 2, 64))
        v = jax.random.normal(ks[2], (1, 512, 2, 64))
        outs = [fa_kernel(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
                for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=1e-5, rtol=1e-5)

    def test_grad_path(self):
        """custom_vjp backward (reference remat) is differentiable."""
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))

        def loss(q, k, v):
            return jnp.sum(ops.flash_attention(q, k, v, True, True) ** 2)

        g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

        def loss_ref(q, k, v):
            return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

        g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)


class TestFlashDecode:
    @pytest.mark.parametrize("B,S,H,KVH,hd", [
        (1, 512, 4, 2, 64),
        (2, 1024, 8, 8, 64),
        (3, 512, 14, 2, 64),     # qwen2-0.5b head layout
        (2, 2048, 8, 1, 128),    # MQA long cache
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, B, S, H, KVH, hd, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, hd), dtype)
        kc = jax.random.normal(ks[1], (B, S, KVH, hd), dtype)
        vc = jax.random.normal(ks[2], (B, S, KVH, hd), dtype)
        lengths = jnp.asarray(
            np.random.RandomState(0).randint(1, S, size=(B,)), jnp.int32)
        out = fd_kernel(q, kc, vc, lengths, block_k=256, interpret=True)
        expected = ref.decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expected, np.float32),
            **tols(dtype))


class TestRMSNorm:
    @pytest.mark.parametrize("N,D", [(256, 512), (1024, 960), (512, 896)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fwd(self, N, D, dtype):
        ks = jax.random.split(KEY, 2)
        x = jax.random.normal(ks[0], (N, D), dtype)
        s = jax.random.normal(ks[1], (D,), jnp.float32) + 1.0
        out = rmsnorm_fwd(x, s, interpret=True)
        expected = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expected, np.float32),
            **tols(dtype))

    def test_bwd_matches_autodiff(self):
        ks = jax.random.split(KEY, 3)
        x = jax.random.normal(ks[0], (512, 256))
        s = jax.random.normal(ks[1], (256,)) + 1.0
        g = jax.random.normal(ks[2], (512, 256))
        dx, ds = rmsnorm_bwd(x, s, g, interpret=True)
        ds = jnp.sum(ds, axis=0)
        ref_dx, ref_ds = jax.vjp(lambda x_, s_: ref.rmsnorm_ref(x_, s_), x, s)[1](g)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(ref_dx),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(ds), np.asarray(ref_ds),
                                   atol=1e-3, rtol=1e-3)

    def test_custom_vjp_op(self):
        x = jax.random.normal(KEY, (256, 128))
        s = jnp.ones((128,))
        f = lambda x_, s_: jnp.sum(ops.rmsnorm(x_, s_, 1e-6, True) ** 2)
        fr = lambda x_, s_: jnp.sum(ref.rmsnorm_ref(x_, s_) ** 2)
        gx, gs = jax.grad(f, argnums=(0, 1))(x, s)
        rx, rs = jax.grad(fr, argnums=(0, 1))(x, s)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(rs), atol=1e-3)


class TestSSDChunk:
    @pytest.mark.parametrize("B,Q,nh,hp,ds", [
        (1, 64, 8, 32, 32),
        (2, 128, 16, 64, 64),
        (1, 256, 8, 64, 128),    # mamba2-1.3b-like chunk
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, B, Q, nh, hp, ds, dtype):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (B, Q, nh, hp), dtype)
        b = jax.random.normal(ks[1], (B, Q, ds), dtype)
        c = jax.random.normal(ks[2], (B, Q, ds), dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, Q, nh))) * 0.1
        a_log = jax.random.uniform(ks[4], (nh,), minval=0.0, maxval=2.0)
        y, st, dec = ssd_kernel(x, b, c, dt.astype(dtype), a_log,
                                block_h=max(nh // 2, 1), interpret=True)
        y_r, st_r, dec_r = ref.ssd_chunk_ref(x, b, c, dt.astype(dtype), a_log)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_r, np.float32), **tols(dtype))
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_r),
                                   atol=3e-2 if dtype == jnp.bfloat16 else 3e-5,
                                   rtol=3e-2 if dtype == jnp.bfloat16 else 3e-5)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(dec_r),
                                   atol=1e-5, rtol=1e-5)
