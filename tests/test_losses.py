"""Chunked-vocab cross entropy vs the plain formulation (values + grads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry
from repro.train.losses import chunked_vocab_xent, plain_xent
from tests.conftest import reduce_cfg

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("V,chunk", [(256, 64), (250, 64), (100, 128), (512, 512)])
@pytest.mark.parametrize("transpose", [False, True])
def test_matches_plain(V, chunk, transpose):
    ks = jax.random.split(KEY, 3)
    B, S, D = 2, 8, 16
    x = jax.random.normal(ks[0], (B, S, D))
    table = jax.random.normal(ks[1], (D, V) if transpose else (V, D)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    logits = (jnp.einsum("bsd,dv->bsv", x, table) if transpose
              else jnp.einsum("bsd,vd->bsv", x, table)).astype(jnp.float32)
    ref = plain_xent(logits, labels)
    out = chunked_vocab_xent(x, table, labels, chunk, transpose)
    np.testing.assert_allclose(float(out), float(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("transpose", [False, True])
def test_gradients_match_plain(transpose):
    ks = jax.random.split(KEY, 3)
    B, S, D, V = 2, 8, 16, 200
    x = jax.random.normal(ks[0], (B, S, D))
    table = jax.random.normal(ks[1], (D, V) if transpose else (V, D)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)

    def loss_chunked(x, t):
        return chunked_vocab_xent(x, t, labels, 64, transpose)

    def loss_plain(x, t):
        lg = (jnp.einsum("bsd,dv->bsv", x, t) if transpose
              else jnp.einsum("bsd,vd->bsv", x, t)).astype(jnp.float32)
        return plain_xent(lg, labels)

    gx, gt = jax.grad(loss_chunked, argnums=(0, 1))(x, table)
    rx, rt = jax.grad(loss_plain, argnums=(0, 1))(x, table)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(rt), atol=1e-5,
                               rtol=1e-4)


def test_model_loss_impl_equivalence(rng):
    """transformer.loss_fn(plain) == loss_fn(chunked_vocab) incl. grads."""
    cfg_p = reduce_cfg(get_config("qwen2-0.5b"))
    cfg_c = cfg_p.with_overrides(loss_impl="chunked_vocab", loss_vocab_chunk=64)
    params = registry.init_params(cfg_p, rng)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 256,
             "labels": jnp.ones((2, 16), jnp.int32)}
    (lp, _), gp = jax.value_and_grad(
        lambda p: registry.loss_fn(p, cfg_p, batch), has_aux=True)(params)
    (lc, _), gc = jax.value_and_grad(
        lambda p: registry.loss_fn(p, cfg_c, batch), has_aux=True)(params)
    np.testing.assert_allclose(float(lp), float(lc), atol=1e-4, rtol=1e-4)
    # grads agree to bf16 rounding (the two paths round differently)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), gp, gc)
    assert max(jax.tree.leaves(errs)) < 2e-2, errs


def test_untied_model_loss_impl_equivalence(rng):
    cfg_p = reduce_cfg(get_config("pixtral-12b"))
    cfg_c = cfg_p.with_overrides(loss_impl="chunked_vocab", loss_vocab_chunk=64)
    params = registry.init_params(cfg_p, rng)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 256,
             "labels": jnp.ones((2, 16), jnp.int32)}
    lp, _ = registry.loss_fn(params, cfg_p, batch)
    lc, _ = registry.loss_fn(params, cfg_c, batch)
    np.testing.assert_allclose(float(lp), float(lc), atol=1e-4, rtol=1e-4)
