"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU, asserting shapes + finiteness.
(The FULL configs are exercised only via the dry-run, per the assignment.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import registry
from tests.conftest import reduce_cfg

ARCHS = list_configs()


def make_batch(cfg, B=2, S=16):
    batch = {
        "tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size,
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jnp.ones((B, cfg.encoder_frames, cfg.d_model),
                                           jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step(self, arch, rng):
        cfg = reduce_cfg(get_config(arch))
        params = registry.init_params(cfg, rng)
        batch = make_batch(cfg)
        (loss, aux), grads = jax.jit(
            jax.value_and_grad(lambda p, b: registry.loss_fn(p, cfg, b),
                               has_aux=True))(params, batch)
        assert np.isfinite(float(loss)), arch
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0, arch

    def test_forward_shapes(self, arch, rng):
        cfg = reduce_cfg(get_config(arch))
        params = registry.init_params(cfg, rng)
        batch = make_batch(cfg, B=2, S=16)
        logits, aux = jax.jit(lambda p, b: registry.forward(p, cfg, b))(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_size), (arch, logits.shape)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    def test_decode_step(self, arch, rng):
        cfg = reduce_cfg(get_config(arch))
        params = registry.init_params(cfg, rng)
        B, S = 2, 32
        cache = registry.init_cache(cfg, B, S)
        toks = jnp.ones((B, 1), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        step = jax.jit(lambda p, c, t, q: registry.decode_step(p, cfg, c, t, q))
        logits, cache = step(params, cache, toks, pos)
        assert logits.shape == (B, 1, cfg.vocab_size), arch
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
        # second token with updated positions
        logits2, cache = step(params, cache, toks, pos + 1)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


class TestDecodePrefillConsistency:
    """Token-by-token decode must reproduce the parallel forward."""

    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b", "zamba2-1.2b"])
    def test_logits_match(self, arch, rng):
        cfg = reduce_cfg(get_config(arch))
        params = registry.init_params(cfg, rng)
        B, S = 1, 8
        toks = (jnp.arange(S, dtype=jnp.int32) * 7 % cfg.vocab_size)[None]
        batch = {"tokens": toks}
        full_logits, _ = registry.forward(params, cfg, batch)

        cache = registry.init_cache(cfg, B, 16)
        step = jax.jit(lambda p, c, t, q: registry.decode_step(p, cfg, c, t, q))
        got = []
        for t in range(S):
            logits, cache = step(params, cache, toks[:, t:t + 1],
                                 jnp.full((B,), t, jnp.int32))
            got.append(np.asarray(logits[:, 0], np.float32))
        got = np.stack(got, axis=1)
        np.testing.assert_allclose(
            got, np.asarray(full_logits, np.float32), atol=5e-2, rtol=5e-2)


class TestParamCounts:
    """Full configs must land near the published sizes."""

    EXPECTED_B = {
        "qwen2-0.5b": (0.40, 0.60), "qwen2.5-3b": (2.8, 3.4),
        "smollm-360m": (0.30, 0.42), "llama3-405b": (390, 420),
        "granite-moe-3b-a800m": (3.0, 3.6), "grok-1-314b": (300, 330),
        "zamba2-1.2b": (1.0, 1.4), "whisper-tiny": (0.03, 0.08),
        "pixtral-12b": (11.5, 13.0), "mamba2-1.3b": (1.2, 1.45),
    }

    @pytest.mark.parametrize("arch", ARCHS)
    def test_param_count(self, arch):
        cfg = get_config(arch)
        n = registry.param_count(cfg) / 1e9
        lo, hi = self.EXPECTED_B[arch]
        assert lo <= n <= hi, f"{arch}: {n:.3f}B not in [{lo},{hi}]"

    def test_moe_active_counts(self):
        g = get_config("granite-moe-3b-a800m")
        active = registry.param_count(g, active_only=True) / 1e9
        assert 0.7 <= active <= 1.0, active
        k = get_config("grok-1-314b")
        active = registry.param_count(k, active_only=True) / 1e9
        assert 70 <= active <= 95, active
