"""MoE dispatch correctness against a dense per-token oracle, and the
explicitly placed expert-parallel all-to-all dispatch (user-space Bruck
vs native in-program) for the granite many-tiny-expert config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from tests._multidevice import run_with_devices


def moe_oracle(p, x, cfg):
    """Per-token loop: route to top-k experts, NO capacity drops."""
    mc = cfg.moe
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, mc.top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    out = np.zeros((B, S, D), np.float32)
    xn = np.asarray(x, np.float32)
    for b in range(B):
        for s in range(S):
            for k in range(mc.top_k):
                e = int(idx[b, s, k])
                xe = xn[b, s]
                h = (jax.nn.silu(xe @ np.asarray(p["wi_gate"][e]))
                     * (xe @ np.asarray(p["wi_up"][e])))
                out[b, s] += float(vals[b, s, k]) * np.asarray(
                    h @ np.asarray(p["wo"][e]))
    return out


def make_cfg(E=4, K=2, F=32, group=64, cf=8.0):
    base = get_config("grok-1-314b")
    return base.with_overrides(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        vocab_size=64,
        moe=base.moe.__class__(num_experts=E, top_k=K, expert_d_ff=F,
                               capacity_factor=cf, group_size=group))


class TestMoEOracle:
    def test_matches_dense_loop_with_ample_capacity(self, rng):
        """With capacity_factor high enough that nothing drops, the
        GShard dispatch must equal the per-token dense computation."""
        cfg = make_cfg(cf=8.0)
        p = L.init_tree(L.moe_spec(cfg), rng)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        y, aux = L.moe_apply(p, x, cfg)
        ref = moe_oracle(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                                   atol=1e-4, rtol=1e-3)

    def test_capacity_drops_reduce_output_norm(self, rng):
        """Tiny capacity must drop tokens: output norm strictly below the
        no-drop case, never above."""
        p = L.init_tree(L.moe_spec(make_cfg()), rng)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32))
        y_full, _ = L.moe_apply(p, x, make_cfg(cf=8.0))
        y_tight, _ = L.moe_apply(p, x, make_cfg(cf=0.25))
        assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))

    def test_aux_loss_uniform_router_is_one_scaled(self, rng):
        """With a zero router (uniform probs), the Switch aux loss equals
        E · Σ (1/E · f_e) · w = w (perfect balance)."""
        cfg = make_cfg(E=4, K=1)
        p = L.init_tree(L.moe_spec(cfg), rng)
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"])
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32))
        _, aux = L.moe_apply(p, x, cfg)
        # uniform probs: me = 1/E; top-1 ties broken deterministically but
        # sum over e of me*fe = 1/E ⇒ aux = E * 1/E * w = w
        assert abs(float(aux) / cfg.moe.aux_loss_weight - 1.0) < 0.05

    def test_gradients_flow_to_router_and_experts(self, rng):
        cfg = make_cfg()
        p = L.init_tree(L.moe_spec(cfg), rng)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 32))

        def loss(p):
            y, aux = L.moe_apply(p, x, cfg)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(loss)(p)
        for name in ("router", "wi_gate", "wi_up", "wo"):
            assert float(jnp.sum(jnp.abs(g[name]))) > 0, name


# ---------------------------------------------------------------------------
# Expert-parallel dispatch: user-space Bruck all-to-all vs native
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("n_devices", [2, 4])
def test_moe_dispatch_alltoall_user_matches_native(n_devices):
    """granite-moe-3b-a800m dispatch, both transposes, both directions:
    the engine-driven Bruck ialltoall must move exactly the blocks the
    native all_to_all moves — bit-identical global arrays — and the full
    expert-parallel apply must be bit-identical to the plain einsum
    path."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.collectives.nonblocking import UserCollectives
        from repro.models import layers as L

        n = {n_devices}
        mesh = compat.make_mesh((n,), ('model',))
        base = get_config('granite-moe-3b-a800m')
        cfg = base.with_overrides(
            num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
            head_dim=16, vocab_size=64,
            moe=base.moe.__class__(num_experts=8, top_k=2, expert_d_ff=16,
                                   capacity_factor=2.0, group_size=16))
        p = L.init_tree(L.moe_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32),
                              jnp.float32)

        eng = ProgressEngine()
        coll = UserCollectives(eng)

        # raw transpose: both directions, user == native, bit for bit
        G, E, C, d = 8, 8, 4, 32
        xe = jax.random.normal(jax.random.PRNGKey(2), (G, E, C, d))
        for reverse in (False, True):
            nat = L.moe_dispatch_alltoall(xe, mesh, 'model',
                                          reverse=reverse)
            usr = L.moe_dispatch_alltoall(xe, mesh, 'model',
                                          reverse=reverse, coll=coll)
            assert np.array_equal(np.asarray(nat), np.asarray(usr)), \
                f'dispatch diverged (reverse={{reverse}})'
        # round trip is the identity
        fwd = L.moe_dispatch_alltoall(xe, mesh, 'model', coll=coll)
        back = L.moe_dispatch_alltoall(fwd, mesh, 'model', reverse=True,
                                       coll=coll)
        assert np.array_equal(np.asarray(back), np.asarray(xe))

        # end to end: plain einsum path == expert-parallel (native) ==
        # expert-parallel (user), bit for bit
        y_ref, aux_ref = L.moe_apply(p, x, cfg)
        y_nat, aux_nat = L.moe_apply_expert_parallel(p, x, cfg, mesh,
                                                     'model')
        y_usr, aux_usr = L.moe_apply_expert_parallel(p, x, cfg, mesh,
                                                     'model', coll=coll)
        assert np.array_equal(np.asarray(y_ref), np.asarray(y_nat))
        assert np.array_equal(np.asarray(y_nat), np.asarray(y_usr))
        assert float(aux_ref) == float(aux_nat) == float(aux_usr)
        coll.close()
        print('MOE_A2A_USER_NATIVE_OK')
    """, n_devices=n_devices)
    assert "MOE_A2A_USER_NATIVE_OK" in out
