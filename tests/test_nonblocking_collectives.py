"""Nonblocking user-space collectives (paper §4.7 on the engine).

Equivalence vs the native ops runs in multi-device subprocesses
(1/2/4 devices, odd and power-of-two payloads, several chunk counts);
the pipeline mechanics — failure propagation, exactly-once completion
under random drain orderings, eager validation — run in-process with
host-only fake schedules (no devices needed).
"""
import random
import types

import pytest

from tests._multidevice import run_with_devices


# ---------------------------------------------------------------------------
# Equivalence vs native (subprocess, 1/2/4 devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_iallreduce_matches_psum(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        for D in (33, 64):                      # odd and power-of-two
            x = jax.random.normal(jax.random.PRNGKey(D), (n * 2, 3, D))
            native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
            for alg in S.ALGORITHMS:
                for K in (1, 3):
                    req = coll.iallreduce(x, mesh, "x", algorithm=alg,
                                          chunks=K)
                    assert not req.is_complete, (
                        f"{{alg}} K={{K}}: complete at issue time")
                    out = req.wait(timeout=120)
                    np.testing.assert_allclose(
                        np.asarray(out), np.asarray(native),
                        atol=1e-4, rtol=1e-4, err_msg=f"{{alg}} D={{D}} K={{K}}")
                    assert req.rounds_done == req.rounds_total
        coll.close()
        assert coll.failed == 0
        print("IALLREDUCE_OK")
    """, n_devices=n_devices)
    assert "IALLREDUCE_OK" in out


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_irs_iag_ia2a_match_native(n_devices):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)

        # reduce-scatter vs tiled psum_scatter
        x = jax.random.normal(jax.random.PRNGKey(0), (n * 2, 2, n * 8))
        if n == 1:
            nat = x
        else:
            nat = jax.jit(compat.shard_map(
                lambda v: jax.lax.psum_scatter(
                    v, "x", scatter_dimension=v.ndim - 1, tiled=True),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        for K in (1, 2, 4):
            out = coll.ireduce_scatter(x, mesh, "x", chunks=K).wait(timeout=120)
            np.testing.assert_allclose(np.asarray(out), np.asarray(nat),
                                       atol=1e-4, rtol=1e-4,
                                       err_msg=f"rs K={{K}}")

        # all-gather vs tiled all_gather
        x = jax.random.normal(jax.random.PRNGKey(1), (n * 2, 2, 6))
        if n == 1:
            nat = x
        else:
            nat = jax.jit(compat.shard_map(
                lambda v: jax.lax.all_gather(v, "x", axis=v.ndim - 1,
                                             tiled=True),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        for K in (1, 2, 3):
            out = coll.iallgather(x, mesh, "x", chunks=K).wait(timeout=120)
            np.testing.assert_allclose(np.asarray(out), np.asarray(nat),
                                       atol=1e-6, err_msg=f"ag K={{K}}")

        # all-to-all vs native block transpose
        x = jax.random.normal(jax.random.PRNGKey(2), (n * n, 5))
        if n == 1:
            nat = x
        else:
            nat = jax.jit(compat.shard_map(
                lambda v: jax.lax.all_to_all(
                    v.reshape(n, 1, 5), "x", 0, 0,
                    tiled=False).reshape(n, 5),
                mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        for K in (1, 2, 5):
            out = coll.ialltoall(x, mesh, "x", chunks=K).wait(timeout=120)
            np.testing.assert_allclose(np.asarray(out), np.asarray(nat),
                                       atol=1e-6, err_msg=f"a2a K={{K}}")
        coll.close()
        print("IRS_IAG_IA2A_OK")
    """, n_devices=n_devices)
    assert "IRS_IAG_IA2A_OK" in out


def test_non_pow2_falls_back_and_matches():
    """Eager pow2 validation: on 3 devices the XOR-partner algorithms
    warn and fall back to ring — and still match native."""
    out = run_with_devices("""
        import warnings
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        from repro.collectives import schedules as S
        n = 3
        mesh = compat.make_mesh((n,), ("x",))
        x = jax.random.normal(jax.random.PRNGKey(0), (n * 2, 33))
        native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        # shard_map wrapper path
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = S.allreduce_under_shard_map(x, mesh, "x", "halving_doubling")
            assert any("power-of-two" in str(i.message) for i in w), w
        np.testing.assert_allclose(np.asarray(out), np.asarray(native),
                                   atol=1e-4, rtol=1e-4)
        # nonblocking path
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            req = coll.iallreduce(x, mesh, "x",
                                  algorithm="recursive_doubling", chunks=2)
            assert any("power-of-two" in str(i.message) for i in w), w
        assert req.algorithm == "ring"
        np.testing.assert_allclose(np.asarray(req.wait(timeout=120)),
                                   np.asarray(native), atol=1e-4, rtol=1e-4)
        coll.close()
        print("FALLBACK_OK")
    """, n_devices=3)
    assert "FALLBACK_OK" in out


def test_engine_grad_reducer_matches_sum():
    """EngineGradReducer: bucketed stacked-gradient reduction equals the
    plain cross-device mean, through buckets and chunk pipelining."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives.overlap import EngineGradReducer
        n = 4
        mesh = compat.make_mesh((n,), ("data",))
        eng = ProgressEngine()
        red = EngineGradReducer(mesh, "data", engine=eng, chunks=3,
                                bucket_bytes=64, mean=True)
        grads = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (n, 8, 16)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (n, 16)),
            "s": jax.random.normal(jax.random.PRNGKey(2), (n,)),
        }
        handle = red.iallreduce_tree(grads)
        assert len(handle.requests) >= 2, "expected multiple buckets"
        out = handle.wait(timeout=120)
        for k, g in grads.items():
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(g).mean(0),
                                       atol=1e-5, err_msg=k)
        red.close()
        print("REDUCER_OK")
    """, n_devices=4)
    assert "REDUCER_OK" in out


# ---------------------------------------------------------------------------
# Pipeline mechanics (in-process, host-only fake schedules)
# ---------------------------------------------------------------------------

from repro.core import DEFERRED, ProgressEngine  # noqa: E402
from repro.collectives import nonblocking as NB  # noqa: E402


def make_coll(policy=None):
    eng = ProgressEngine()
    kwargs = {"policy": policy} if policy else {}
    return NB.UserCollectives(eng, **kwargs)


def fake_schedule(stages):
    """A _Schedule of plain host callables — floats instead of arrays;
    jax_future treats objects without .is_ready() as immediately ready,
    so the pipeline machinery runs without any devices."""
    sched = NB._Schedule.__new__(NB._Schedule)
    sched.stages = tuple(stages)
    return sched


class TestPipelineMechanics:
    def test_failure_at_issue_time_fails_request(self):
        coll = make_coll()

        def boom(v):
            raise RuntimeError("round-0 boom")

        req = coll._issue("allreduce", "ring", [fake_schedule([boom])],
                          [1.0], lambda parts: parts[0])
        assert req.failed
        with pytest.raises(RuntimeError, match="round-0 boom"):
            req.value()
        assert coll.failed == 1
        coll.close()

    def test_failure_mid_pipeline_propagates_into_request(self):
        coll = make_coll()
        ran = []

        def ok(v):
            ran.append(v)
            return v + 1

        def boom(v):
            raise ValueError("round-1 boom")

        req = coll._issue("allreduce", "ring",
                          [fake_schedule([ok, boom])], [1.0],
                          lambda parts: parts[0])
        assert not req.is_complete          # round 0 dispatched fine
        with pytest.raises(ValueError, match="round-1 boom"):
            req.wait(timeout=5.0)
        assert req.failed
        assert ran == [1.0]
        assert coll.failed == 1
        # one failing chunk must not wedge a sibling: stream drains clean
        coll.close()

    def test_one_bad_chunk_fails_request_but_good_chunks_finish(self):
        coll = make_coll()
        done = []

        def ok(v):
            done.append(v)
            return v

        def boom(v):
            raise RuntimeError("chunk-1 boom")

        req = coll._issue(
            "allreduce", "ring",
            [fake_schedule([ok, ok]), fake_schedule([ok, boom])],
            [1.0, 2.0], lambda parts: parts)
        with pytest.raises(RuntimeError, match="chunk-1 boom"):
            req.wait(timeout=5.0)
        # the failure is counted once per REQUEST, not once per chunk
        assert coll.failed == 1
        assert coll.in_flight == 0
        coll.close()                        # good chunk's tasks all retire

    def test_failure_abandons_sibling_chunks(self):
        """Once one chunk fails the request, sibling chunks stop
        dispatching further rounds (no wasted work on the error path)."""
        coll = make_coll()
        ran = []

        def boom(v):
            raise RuntimeError("boom")

        def late(v):
            ran.append(v)
            return v

        # chunk 0 fails at issue time, so chunk 1 (issued after) must
        # never run any of its stages
        req = coll._issue("allreduce", "ring",
                          [fake_schedule([boom]),
                           fake_schedule([late, late, late])],
                          [1.0, 2.0], lambda parts: parts)
        assert req.failed
        for _ in range(10):
            coll.engine.progress(coll.stream)
        assert ran == []
        assert coll.failed == 1
        coll.close()

    def test_deferred_without_executor_wait_self_drains(self):
        """Regression: with policy=DEFERRED and no executor adopting the
        queue, req.wait() must drain the ready list itself — otherwise
        every multi-stage collective times out with all work 'ready'."""
        coll = make_coll(policy=DEFERRED)
        req = coll._issue("allreduce", "ring",
                          [fake_schedule([lambda v: v + 1,
                                          lambda v: v * 10])],
                          [1.0], lambda parts: parts[0])
        assert req.wait(timeout=5.0) == 20.0
        coll.close()

    def test_close_timeout_is_retryable(self):
        """A drain timeout must not leave the context half-closed: a
        retry close() after the blocker clears drains and frees."""
        coll = make_coll()
        gate = {"open": False}
        blocker = types.SimpleNamespace(is_ready=lambda: gate["open"])
        req = coll._issue("allreduce", "ring",
                          [fake_schedule([lambda v: blocker])], [1.0],
                          lambda parts: parts[0])
        with pytest.raises(TimeoutError):
            coll.close(timeout=0.05)
        gate["open"] = True                  # blocker clears
        coll.close(timeout=5.0)              # retry succeeds
        assert req.is_complete
        assert coll.stream not in coll.engine._streams

    def test_default_collectives_conflicting_kwargs_raise(self):
        eng = ProgressEngine()
        ctx = NB.default_collectives(eng)
        assert NB.default_collectives(eng) is ctx
        with pytest.raises(ValueError, match="configured differently"):
            NB.default_collectives(eng, policy=DEFERRED)
        ctx.close()
        # after close, a fresh context with the new policy is built
        ctx2 = NB.default_collectives(eng, policy=DEFERRED)
        assert ctx2.queue.policy == DEFERRED
        ctx2.close()

    def test_join_failure_fails_request(self):
        coll = make_coll()

        def bad_join(parts):
            raise RuntimeError("join boom")

        req = coll._issue("allreduce", "ring",
                          [fake_schedule([lambda v: v])], [1.0], bad_join)
        with pytest.raises(RuntimeError, match="join boom"):
            req.wait(timeout=5.0)
        coll.close()

    def test_closed_context_rejects_issues(self):
        coll = make_coll()
        coll.close()
        mesh = types.SimpleNamespace(shape={"x": 2})
        with pytest.raises(RuntimeError, match="closed"):
            coll.iallreduce(None, mesh, "x")

    def test_eager_shape_validation(self):
        coll = make_coll()
        mesh = types.SimpleNamespace(shape={"x": 3})
        arr = types.SimpleNamespace(shape=(6, 10))
        with pytest.raises(ValueError, match="not divisible"):
            coll.ireduce_scatter(arr, mesh, "x")
        arr2 = types.SimpleNamespace(shape=(7, 9))
        with pytest.raises(ValueError, match="not divisible"):
            coll.ialltoall(arr2, mesh, "x")
        with pytest.raises(ValueError, match="unknown allreduce algorithm"):
            coll.iallreduce(arr, mesh, "x", algorithm="nope")
        # 1-D payloads would chunk the sharded dim itself: rejected eagerly
        one_d = types.SimpleNamespace(shape=(6,))
        for op in ("iallreduce", "ireduce_scatter", "iallgather",
                   "ialltoall"):
            with pytest.raises(ValueError, match="at least 2-D"):
                getattr(coll, op)(one_d, mesh, "x")
        coll.close()

    def test_abandon_close_with_in_flight_work_does_not_raise(self):
        """close(drain=False) — the __exit__ exception path — must not
        raise over the application's original error even with rounds
        still pending; pending continuations are cancelled, the busy
        stream is left registered instead of freed."""
        coll = make_coll()
        never_ready = types.SimpleNamespace(is_ready=lambda: False)

        def stall(v):
            return never_ready                  # future that never fires

        req = coll._issue("allreduce", "ring",
                          [fake_schedule([stall, lambda v: v])], [1.0],
                          lambda parts: parts[0])
        assert coll.stream.pending
        coll.close(drain=False)                 # must not raise
        assert not req.is_complete              # abandoned, not completed
        # the stream stays registered; its tasks retire on later sweeps
        assert coll.stream in coll.engine._streams


def run_random_drain(rng, num_chunks, num_stages):
    """One exactly-once trial: chunked fake schedules on a DEFERRED
    queue, progressed/drained in a random interleave."""
    coll = make_coll(policy=DEFERRED)
    eng, stream, queue = coll.engine, coll.stream, coll.queue
    counts = [[0] * num_stages for _ in range(num_chunks)]

    def stage(c, s):
        def fn(v):
            counts[c][s] += 1
            return v + 1
        return fn

    scheds = [fake_schedule([stage(c, s) for s in range(num_stages)])
              for c in range(num_chunks)]
    joins = []

    def join(parts):
        joins.append(list(parts))
        return sum(parts)

    req = coll._issue("allreduce", "ring", scheds,
                      [float(c) for c in range(num_chunks)], join)
    assert not req.is_complete
    steps = 0
    while not req.is_complete and steps < 10_000:
        op = rng.randrange(3)
        if op == 0:
            eng.progress(stream)
        elif op == 1:
            queue.drain(max_items=rng.randrange(1, 3))
        else:
            eng.progress(stream)
            queue.drain()
        steps += 1
    assert req.is_complete, "pipeline wedged under random drain ordering"
    # exactly once: every stage of every chunk ran once, one join
    assert counts == [[1] * num_stages for _ in range(num_chunks)], counts
    assert len(joins) == 1
    assert req.value() == sum(c + num_stages for c in range(num_chunks))
    coll.close()


class TestExactlyOnce:
    @pytest.mark.parametrize("seed", range(8))
    def test_random_drain_orderings(self, seed):
        rng = random.Random(seed)
        run_random_drain(rng, num_chunks=rng.randrange(1, 5),
                         num_stages=rng.randrange(1, 6))

    def test_hypothesis_property(self):
        hyp = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(seed=st.integers(0, 2**32 - 1),
               chunks=st.integers(1, 6), stages=st.integers(1, 6))
        def prop(seed, chunks, stages):
            run_random_drain(random.Random(seed), chunks, stages)

        prop()


def test_trainer_rejects_user_backend_without_split_step(tmp_path):
    from repro.train.train_loop import Trainer, TrainLoopConfig
    cfg = TrainLoopConfig(collective_backend="user",
                          checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="split_step"):
        Trainer(lambda *a: None, None, None, None, cfg,
                engine=ProgressEngine())
