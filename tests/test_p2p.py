"""User-space nonblocking point-to-point: single-hop ring transfers as
CollectiveRequest handles (isend/irecv matching queues, persistent
send_init/recv_init channels, epoch invalidation) — all in multi-device
subprocesses."""
from tests._multidevice import run_with_devices


def test_isend_irecv_roundtrip_and_matching():
    out = run_with_devices("""
        import collections
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.collectives.p2p import P2P
        from repro.core import ProgressEngine

        eng = ProgressEngine()
        p2p = P2P(eng)
        mesh = compat.make_mesh((4,), ("x",))
        n = 4
        x = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)

        # forward ring: recv row i = what rank i-1 sent = roll(x, +1)
        sreq = p2p.isend(x, mesh, "x")
        rreq = p2p.irecv(x, mesh, "x")
        got = rreq.wait(timeout=120)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.roll(np.asarray(x), 1, axis=0))
        sreq.wait(timeout=120)   # send handle retires with the transfer
        assert sreq.value() is None

        # reverse ring: recv row i = what rank i+1 sent = roll(x, -1)
        rrev = p2p.irecv(x, mesh, "x", reverse=True)
        p2p.isend(x, mesh, "x", reverse=True)
        np.testing.assert_array_equal(np.asarray(rrev.wait(timeout=120)),
                                      np.roll(np.asarray(x), -1, axis=0))
        print("ROUNDTRIP_OK")

        # unexpected-message queue: two sends posted before any recv
        # must match the recvs FIFO (non-overtaking rule)
        a = x + 100.0
        b = x + 200.0
        p2p.isend(a, mesh, "x")
        p2p.isend(b, mesh, "x")
        assert p2p.unexpected >= 2
        r1 = p2p.irecv(x, mesh, "x")
        r2 = p2p.irecv(x, mesh, "x")
        np.testing.assert_array_equal(np.asarray(r1.wait(timeout=120)),
                                      np.roll(np.asarray(a), 1, axis=0))
        np.testing.assert_array_equal(np.asarray(r2.wait(timeout=120)),
                                      np.roll(np.asarray(b), 1, axis=0))
        assert p2p.matched >= 3
        print("FIFO_OK")

        # tags partition the matching space: a recv on tag 1 must not
        # consume the tag-0 send
        p2p.isend(a, mesh, "x", tag=0)
        rt = p2p.irecv(x, mesh, "x", tag=1)
        assert not rt.is_complete
        p2p.isend(b, mesh, "x", tag=1)
        np.testing.assert_array_equal(np.asarray(rt.wait(timeout=120)),
                                      np.roll(np.asarray(b), 1, axis=0))
        p2p.irecv(x, mesh, "x", tag=0).wait(timeout=120)
        print("TAG_OK")

        # one-shot fused sendrecv
        sr = p2p.sendrecv(x, mesh, "x")
        np.testing.assert_array_equal(np.asarray(sr.wait(timeout=120)),
                                      np.roll(np.asarray(x), 1, axis=0))
        stats_ok = p2p.stream.completions > 0
        p2p.close()
        assert stats_ok
        print("P2P_OK")
    """, n_devices=4)
    assert "ROUNDTRIP_OK" in out and "FIFO_OK" in out
    assert "TAG_OK" in out and "P2P_OK" in out


def test_persistent_channel_restarts_and_executor_issue():
    out = run_with_devices("""
        import threading
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.collectives.p2p import P2P
        from repro.core import ProgressEngine, ProgressExecutor

        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2).start()
        eng.attach_executor(ex)
        p2p = P2P(eng, executor=ex)
        mesh = compat.make_mesh((2,), ("x",))
        like = jax.ShapeDtypeStruct((2, 4), jnp.float32)

        send = p2p.send_init(like, mesh, "x")
        recv = p2p.recv_init(like, mesh, "x")
        # same signature -> same channel: that IS the match
        assert send.channel is recv.channel
        chan = send.channel
        starts0 = chan.starts

        for i in range(3):
            x = jnp.full((2, 4), float(i + 1))[0] * jnp.ones((2, 4)) \\
                + jnp.arange(2.0)[:, None]
            hop = send.start(x)
            inner = chan.persistent.active   # the hop CollectiveRequest
            got = recv.start().wait(timeout=120)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.roll(np.asarray(x), 1, axis=0))
            hop.wait(timeout=120)
            # persistent user-space request: the issue ran on an
            # executor worker, not this thread (executor-driven start)
            assert inner.issue_thread in ex.worker_thread_idents(), \\
                (inner.issue_thread, ex.worker_thread_idents())
            assert inner.issue_thread != threading.get_ident()
        assert chan.starts == starts0 + 3
        print("PERSISTENT_OK")
        p2p.close()
        ex.shutdown(drain=True, timeout=120)
        print("DONE")
    """, n_devices=2)
    assert "PERSISTENT_OK" in out and "DONE" in out


def test_channel_epoch_invalidation_and_rebuild():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.collectives.p2p import P2P
        from repro.collectives.nonblocking import (MembershipEpoch,
                                                   MembershipError)
        from repro.core import ProgressEngine

        eng = ProgressEngine()
        epoch = MembershipEpoch()
        p2p = P2P(eng, epoch=epoch)
        mesh = compat.make_mesh((4,), ("x",))
        like = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        chan = p2p.channel_init(like, mesh, "x")
        x = jnp.arange(16.0).reshape(4, 4)
        chan.send.start(x)
        chan.recv.start().wait(timeout=120)

        epoch.invalidate(survivors=2, reason="test kill")
        assert chan.stale
        try:
            chan.send.start(x)
            raise SystemExit("stale channel accepted a start")
        except MembershipError:
            pass
        print("STALE_OK")

        # rebuild on the survivors' mesh: persistent program re-planned
        small = compat.make_mesh((2,), ("x",))
        chan.rebuild(small, axis="x")
        y = jnp.arange(8.0).reshape(2, 4)
        chan.send.start(y)
        got = chan.recv.start().wait(timeout=120)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.roll(np.asarray(y), 1, axis=0))
        print("REBUILD_OK")
        p2p.close()
    """, n_devices=4)
    assert "STALE_OK" in out and "REBUILD_OK" in out
