"""BlockAllocator / PagedKVCache / SlotCache invariants.

The core allocator invariants are property-tested twice: with hypothesis
when it is installed (random alloc/extend/free interleavings), and with
a seeded exhaustive-ish driver that always runs, so the invariants are
exercised even in environments without the optional dependency.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve.kvcache import (BlockAllocationError, BlockAllocator,
                                 PagedKVCache, SlotCache)
from conftest import reduce_cfg


# ---------------------------------------------------------------------------
# Allocator invariant checking (shared by both drivers)
# ---------------------------------------------------------------------------

def check_invariants(ba: BlockAllocator) -> None:
    owned = {o: ba.blocks_of(o) for o in ba.owners()}
    all_owned = [b for blocks in owned.values() for b in blocks]
    # no block owned twice (tables of live requests never alias)
    assert len(all_owned) == len(set(all_owned))
    # the reserved scratch block is never handed out
    assert 0 not in all_owned
    # conservation: free + owned == usable pool, always
    assert ba.free_count + len(all_owned) == ba.usable_blocks
    # free list and owned sets are disjoint
    assert not set(ba._free) & set(all_owned)


def drive(ba: BlockAllocator, ops: list[tuple]) -> None:
    """Apply (op, owner, n) steps, checking invariants after each."""
    for op, owner, n in ops:
        if op == "alloc":
            if owner in ba.owners():
                with pytest.raises(BlockAllocationError):
                    ba.alloc(owner, n)
            else:
                got = ba.alloc(owner, n)
                assert (got is None) == (n > ba.free_count + (len(got) if got else 0)) \
                    or got is not None  # alloc returns None only on OOM
        elif op == "extend":
            if owner not in ba.owners():
                with pytest.raises(BlockAllocationError):
                    ba.extend(owner, n)
            else:
                ba.extend(owner, n)
        elif op == "free":
            if owner not in ba.owners():
                with pytest.raises(BlockAllocationError):
                    ba.free(owner)
            else:
                freed = ba.free(owner)
                assert freed >= 1
        check_invariants(ba)


def test_allocator_invariants_seeded():
    """Deterministic random interleavings (runs without hypothesis)."""
    rng = np.random.RandomState(0)
    for trial in range(50):
        num_blocks = int(rng.randint(2, 40))
        ba = BlockAllocator(num_blocks)
        ops = []
        for _ in range(rng.randint(1, 60)):
            op = ["alloc", "extend", "free"][rng.randint(3)]
            owner = f"r{rng.randint(6)}"
            ops.append((op, owner, int(rng.randint(1, 8))))
        drive(ba, ops)


def test_allocator_basics():
    ba = BlockAllocator(10)
    a = ba.alloc("a", 3)
    b = ba.alloc("b", 4)
    assert set(a).isdisjoint(b)
    assert ba.free_count == 9 - 7
    assert ba.alloc("c", 3) is None           # OOM is a signal, not a raise
    assert ba.extend("a", 5) is None
    more = ba.extend("a", 2)
    assert len(more) == 2 and ba.blocks_of("a") == a + more
    assert ba.free("a") == 5
    check_invariants(ba)
    with pytest.raises(BlockAllocationError):
        ba.free("a")
    with pytest.raises(BlockAllocationError):
        ba.alloc("b", 1)                      # duplicate owner raises


def test_allocator_rejects_tiny_pool():
    with pytest.raises(ValueError):
        BlockAllocator(1)                     # only the scratch block


# ---------------------------------------------------------------------------
# Hypothesis drivers (optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(st.sampled_from(["alloc", "extend", "free"]),
                  st.sampled_from(["a", "b", "c", "d"]),
                  st.integers(min_value=1, max_value=6)),
        min_size=1, max_size=40)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(min_value=2, max_value=32), OPS)
    def test_allocator_invariants_hypothesis(num_blocks, ops):
        drive(BlockAllocator(num_blocks), ops)


# ---------------------------------------------------------------------------
# SlotCache free-list behaviour (heap free list, duplicate guard)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_cfg():
    return reduce_cfg(get_config("qwen2-0.5b"), dtype="float32")


def test_slotcache_duplicate_request_raises(tiny_cfg):
    sc = SlotCache(tiny_cfg, 4, 32)
    sc.assign("r0")
    with pytest.raises(ValueError):
        sc.assign("r0")                       # would shadow + leak a slot


def test_slotcache_free_list(tiny_cfg):
    sc = SlotCache(tiny_cfg, 4, 32)
    slots = [sc.assign(f"r{i}") for i in range(4)]
    assert sc.assign("r4") is None
    assert sc.free_count == 0 and sc.free_slots() == []
    sc.release(slots[2])
    sc.release(slots[0])
    # lowest-index-first reuse, reported sorted
    assert [s.index for s in sc.free_slots()] == [0, 2]
    assert sc.assign("r5").index == 0
    assert sc.assign("r6").index == 2
    assert sc.active_count() == 4


# ---------------------------------------------------------------------------
# PagedKVCache: lane + block table behaviour
# ---------------------------------------------------------------------------

def test_paged_assign_claims_lane_and_blocks_atomically(tiny_cfg):
    pc = PagedKVCache(tiny_cfg, lanes=2, max_seq=32, block_size=8,
                      num_blocks=6)            # 5 usable
    lane = pc.assign("a", seq_len=17)          # ceil(17/8) = 3 blocks
    assert lane is not None
    assert len(pc.allocator.blocks_of("a")) == 3
    # 2 blocks left: a 17-token request needs 3 — neither lane nor
    # blocks may be consumed by the failed attempt
    free_lanes = pc.free_count
    assert pc.assign("b", seq_len=17) is None
    assert pc.free_count == free_lanes
    assert pc.allocator.free_count == 2
    # a short request still fits
    assert pc.assign("c", seq_len=8) is not None


def test_paged_tables_track_extension_and_release(tiny_cfg):
    pc = PagedKVCache(tiny_cfg, lanes=2, max_seq=32, block_size=8,
                      num_blocks=9)
    lane = pc.assign("a", seq_len=4)
    t = np.asarray(pc.block_tables())
    assert t.shape == (2, 4)                   # [lanes, max_blocks]
    assert t[lane.index, 0] != 0 and (t[lane.index, 1:] == 0).all()
    assert pc.ensure(lane.index, 7)            # still block 0 of the lane
    assert pc.ensure(lane.index, 8)            # extends into block 1
    t = np.asarray(pc.block_tables())
    assert t[lane.index, 1] != 0
    # tables of concurrent lanes never alias
    lane2 = pc.assign("b", seq_len=32)
    t = np.asarray(pc.block_tables())
    own_a = set(t[lane.index][t[lane.index] != 0])
    own_b = set(t[lane2.index][t[lane2.index] != 0])
    assert own_a.isdisjoint(own_b)
    pc.release(lane)
    t = np.asarray(pc.block_tables())
    assert (t[lane.index] == 0).all()
    assert "a" not in pc.allocator.owners()


def test_paged_ensure_oom_signals_not_raises(tiny_cfg):
    pc = PagedKVCache(tiny_cfg, lanes=2, max_seq=16, block_size=4,
                      num_blocks=6)            # 5 usable, max_blocks=4
    a = pc.assign("a", seq_len=12)             # 3 blocks
    b = pc.assign("b", seq_len=8)              # 2 blocks -> 0 free
    assert pc.allocator.free_count == 0
    assert pc.ensure(a.index, 11)              # covered already
    assert not pc.ensure(a.index, 12)          # OOM: preemption trigger
    pc.release(b)
    assert pc.ensure(a.index, 12)              # freed blocks recycle


def test_paged_duplicate_request_raises(tiny_cfg):
    pc = PagedKVCache(tiny_cfg, lanes=2, max_seq=16, block_size=4)
    pc.assign("a", seq_len=4)
    with pytest.raises(ValueError):
        pc.assign("a", seq_len=4)


def test_paged_pool_must_hold_one_max_seq_request(tiny_cfg):
    with pytest.raises(ValueError):
        # 3 usable blocks of 4 < max_seq 16: a lone request would wedge
        PagedKVCache(tiny_cfg, lanes=2, max_seq=16, block_size=4,
                     num_blocks=4)


def test_paged_default_pool_matches_slot_capacity(tiny_cfg):
    pc = PagedKVCache(tiny_cfg, lanes=3, max_seq=32, block_size=8)
    # default pool: every lane can hold max_seq simultaneously
    lanes = [pc.assign(f"r{i}", seq_len=32) for i in range(3)]
    assert all(l is not None for l in lanes)
    assert pc.allocator.free_count == 0


def test_paged_ssm_family_has_no_blocks():
    cfg = reduce_cfg(get_config("mamba2-1.3b"), dtype="float32")
    pc = PagedKVCache(cfg, lanes=2, max_seq=16, block_size=4, num_blocks=2)
    assert not pc.has_blocks
    lane = pc.assign("a", seq_len=16)          # no blocks consumed
    assert pc.allocator.free_count == pc.allocator.usable_blocks
    assert pc.ensure(lane.index, 15)           # always satisfiable
    # reset_lane zeroes the recurrent state of exactly that lane
    pc.cache = jax.tree.map(lambda a: a + 1.0, pc.cache)
    new = pc.reset_lane(pc.cache, lane.index)
    flat = jax.tree_util.tree_leaves(new)
    for leaf in flat:
        assert float(abs(leaf[:, lane.index]).max()) == 0.0
        assert float(abs(leaf[:, 1 - lane.index]).min()) == 1.0
