"""Persistent collective schedules + round batching.

Equivalence suites run in multi-device subprocesses (1/2/4 devices):
persistent rebind (same handle, successive distinct payloads) and
round-batched vs unbatched outputs use integer-valued payloads so float
sums are exact and results can be asserted *bit-identical* to the native
op.  Handle lifecycle — one outstanding start, failure-then-restart,
cancel, close — runs in-process against fake host-callable plans.
"""
import json
import random
import types

import pytest

from tests._multidevice import run_with_devices

from repro.core import ProgressEngine  # noqa: E402
from repro.core.request import CancelledError  # noqa: E402
from repro.collectives import nonblocking as NB  # noqa: E402
from repro.collectives import schedules as S  # noqa: E402


# ---------------------------------------------------------------------------
# Equivalence vs native (subprocess, 1/2/4 devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_persistent_rebind_bitidentical(n_devices):
    """MPI *_init/Start: one handle, three successive distinct payloads,
    each bit-identical to the native psum (integer-valued payloads make
    the float sums exact, so equality is exact equality)."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        for alg in S.ALGORITHMS:
            h = coll.allreduce_init(
                jax.ShapeDtypeStruct((n * 2, 33), jnp.float32), mesh, "x",
                algorithm=alg, chunks=2)
            for seed in (1, 2, 3):
                x = jax.random.randint(jax.random.PRNGKey(seed),
                                       (n * 2, 33), -8, 8).astype(jnp.float32)
                out = h.start(x).wait(timeout=120)
                np.testing.assert_array_equal(
                    np.asarray(out), np.asarray(native(x)),
                    err_msg=f"{{alg}} seed={{seed}}")
            assert h.starts == 3
            h.close()
        assert coll.failed == 0
        coll.close()
        print("REBIND_OK")
    """, n_devices=n_devices)
    assert "REBIND_OK" in out


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_round_batched_equals_unbatched(n_devices):
    """Round fusion is plain composition: batched (incl. the stacked
    multi-chunk small-payload path) and unbatched issues produce
    bit-identical outputs for every algorithm, and the collectives
    beyond allreduce survive batching too."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.core import ProgressEngine
        from repro.collectives import nonblocking as NB
        from repro.collectives import schedules as S
        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        x = jax.random.randint(jax.random.PRNGKey(0), (n * 2, 3, 40),
                               -8, 8).astype(jnp.float32)
        for alg in S.ALGORITHMS:
            for K in (1, 3):
                ref = coll.iallreduce(x, mesh, "x", algorithm=alg,
                                      chunks=K, round_batch=1).wait(timeout=120)
                for rb in (2, 100, None):       # partial, full, auto
                    got = coll.iallreduce(x, mesh, "x", algorithm=alg,
                                          chunks=K,
                                          round_batch=rb).wait(timeout=120)
                    np.testing.assert_array_equal(
                        np.asarray(got), np.asarray(ref),
                        err_msg=f"{{alg}} K={{K}} rb={{rb}}")
        y = jax.random.randint(jax.random.PRNGKey(1), (n * 2, n * 4),
                               -8, 8).astype(jnp.float32)
        for op, kw in (("ireduce_scatter", {{}}), ("iallgather", {{}})):
            ref = getattr(coll, op)(y, mesh, "x", chunks=2,
                                    round_batch=1).wait(timeout=120)
            got = getattr(coll, op)(y, mesh, "x", chunks=2,
                                    round_batch=100).wait(timeout=120)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=op)
        z = jax.random.randint(jax.random.PRNGKey(2), (n * n, 6),
                               -8, 8).astype(jnp.float32)
        ref = coll.ialltoall(z, mesh, "x", chunks=2,
                             round_batch=1).wait(timeout=120)
        got = coll.ialltoall(z, mesh, "x", chunks=2,
                             round_batch=100).wait(timeout=120)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        coll.close()
        print("BATCH_EQ_OK")
    """, n_devices=n_devices)
    assert "BATCH_EQ_OK" in out


def test_grad_reducer_caches_persistent_handles():
    """EngineGradReducer: one persistent schedule per grad bucket,
    re-started across steps instead of rebuilt — and the reduction still
    equals the plain cross-device mean every step."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core import ProgressEngine
        from repro.collectives.overlap import EngineGradReducer
        n = 4
        mesh = compat.make_mesh((n,), ("data",))
        eng = ProgressEngine()
        red = EngineGradReducer(mesh, "data", engine=eng, chunks=3,
                                bucket_bytes=64, mean=True)
        for step in range(3):
            grads = {
                "w": jax.random.normal(jax.random.PRNGKey(step), (n, 8, 16)),
                "b": jax.random.normal(jax.random.PRNGKey(step + 10), (n, 16)),
            }
            out = red.iallreduce_tree(grads).wait(timeout=120)
            for k, g in grads.items():
                np.testing.assert_allclose(np.asarray(out[k]),
                                           np.asarray(g).mean(0),
                                           atol=1e-5, err_msg=f"{k}@{step}")
        handles = list(red._persistent.values())
        assert len(handles) >= 2                 # one per bucket
        assert all(h.starts == 3 for h in handles), \
            [h.starts for h in handles]
        red.close()
        assert all(h._closed for h in handles)
        print("REDUCER_PERSISTENT_OK")
    """, n_devices=4)
    assert "REDUCER_PERSISTENT_OK" in out


# ---------------------------------------------------------------------------
# Handle lifecycle (in-process, fake host-callable plans)
# ---------------------------------------------------------------------------

def host_schedule(fns):
    """A compiled-view schedule of plain host callables (floats instead
    of arrays: jax_future treats objects without .is_ready() as ready)
    wrapped so PersistentCollective can 'compile' it at any batch."""
    sched = NB._Schedule(tuple(fns))
    return types.SimpleNamespace(num_rounds=len(fns),
                                 compiled=lambda b: sched)


def fake_plan(schedules, split=None, join=None):
    return NB._Plan("allreduce", "ring", None, None, None, None,
                    schedules, split or (lambda x: [x]),
                    join or NB._first, 0, 1)


def make_handle(fns, **plan_kw):
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    plan = fake_plan([host_schedule(fns)], **plan_kw)
    return coll, NB.PersistentCollective(coll, plan, warmup=False)


class TestPersistentLifecycle:
    def test_start_wait_start(self):
        coll, h = make_handle([lambda v: v + 1, lambda v: v * 10])
        assert h.start(1.0).wait(timeout=5) == 20.0
        assert h.start(2.0).wait(timeout=5) == 30.0
        assert h.starts == 2
        coll.close()

    def test_second_start_while_active_raises(self):
        coll, h = make_handle([lambda v: v])
        req = h.start(1.0)
        with pytest.raises(RuntimeError, match="active start"):
            h.start(2.0)
        req.wait(timeout=5)
        h.start(3.0).wait(timeout=5)         # complete -> restartable
        coll.close()

    def test_failure_then_restart_same_handle(self):
        def stage(v):
            if v < 0:
                raise RuntimeError("negative payload boom")
            return v + 1

        coll, h = make_handle([stage])
        bad = h.start(-1.0)
        assert bad.failed
        with pytest.raises(RuntimeError, match="negative payload boom"):
            bad.value()
        good = h.start(5.0)                  # failed start is restartable
        assert good.wait(timeout=5) == 6.0
        assert coll.failed == 1 and coll.completed == 1
        coll.close()

    def test_cancel_then_restart(self):
        gate = {"open": False}
        blocker = types.SimpleNamespace(is_ready=lambda: gate["open"])
        # payload 1.0 stalls on the gated blocker; later payloads flow
        coll, h = make_handle([lambda v: blocker if v == 1.0 else v,
                               lambda v: v])
        req = h.start(1.0)
        assert not req.is_complete
        h.cancel()
        assert req.cancelled and req.failed
        with pytest.raises(CancelledError):
            req.wait(timeout=5)
        assert coll.cancelled == 1 and coll.in_flight == 0
        # cancelled start is restartable; cancel when idle is a no-op
        h.cancel()
        req2 = h.start(2.0)
        gate["open"] = True                  # also unwedges the old task
        assert req2.wait(timeout=5) == 2.0
        coll.close()

    def test_cancel_after_complete_is_noop(self):
        coll, h = make_handle([lambda v: v])
        req = h.start(1.0)
        assert req.wait(timeout=5) == 1.0
        req.cancel()
        assert not req.cancelled and req.value() == 1.0
        assert coll.cancelled == 0
        coll.close()

    def test_closed_handle_rejects_start(self):
        coll, h = make_handle([lambda v: v])
        h.close()
        with pytest.raises(RuntimeError, match="closed"):
            h.start(1.0)
        coll.close()

    def test_shape_dtype_validation(self):
        import jax.numpy as jnp
        from repro import compat
        mesh = compat.make_mesh((1,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        h = coll.allreduce_init(jnp.zeros((2, 4), jnp.float32), mesh, "x")
        with pytest.raises(ValueError, match="shape"):
            h.start(jnp.zeros((2, 5), jnp.float32))
        with pytest.raises(ValueError, match="dtype"):
            h.start(jnp.zeros((2, 4), jnp.int32))
        out = h.start(jnp.ones((2, 4), jnp.float32)).wait(timeout=30)
        assert out.shape == (2, 4)
        coll.close()


# ---------------------------------------------------------------------------
# Round batching mechanics (in-process)
# ---------------------------------------------------------------------------

class TestRoundBatching:
    def test_auto_round_batch_breakpoints(self):
        R = 15
        # latency regime: one dispatch
        assert S.auto_round_batch(128 << 10, R) == R
        assert S.auto_round_batch(S.ROUND_BATCH_SMALL_BYTES, R) == R
        # middle: two dispatches
        mid = S.auto_round_batch(S.ROUND_BATCH_SMALL_BYTES + 1, R)
        assert mid == -(-R // 2)
        assert S.auto_round_batch(S.ROUND_BATCH_LARGE_BYTES, R) == mid
        # bandwidth regime: per-round pipelining
        assert S.auto_round_batch(S.ROUND_BATCH_LARGE_BYTES + 1, R) == 1
        # degenerate schedules never batch
        assert S.auto_round_batch(1, 1) == 1
        assert S.auto_round_batch(1, 0) == 1

    def test_fuse_rounds_is_composition(self):
        fns = [lambda v: v + 1, lambda v: v * 3, lambda v: v - 2]
        assert S.fuse_rounds(fns)(4) == ((4 + 1) * 3) - 2
        f = S.fuse_rounds([fns[0]])
        assert f is fns[0]                   # single round: no wrapper
        with pytest.raises(ValueError):
            S.fuse_rounds([])

    def test_compiled_groups_and_caches(self):
        import jax.numpy as jnp
        from repro import compat
        mesh = compat.make_mesh((1,), ("x",))
        stages = [NB._RoundStage(lambda v, i=i: v + i, donate=i > 0)
                  for i in range(5)]
        rs = NB._RoundSchedule(mesh, "x", stages)
        assert rs.compiled(2).num_rounds == 3        # 2+2+1
        assert rs.compiled(5).num_rounds == 1
        assert rs.compiled(99).num_rounds == 1       # clamped to len
        assert rs.compiled(1).num_rounds == 5
        assert rs.compiled(2) is rs.compiled(2)      # cached per batch
        x = jnp.ones((1, 3))
        for b in (1, 2, 5):
            out = x
            for prog in rs.compiled(b).stages:
                out = prog(out)
            assert float(out[0, 0]) == 1 + 0 + 1 + 2 + 3 + 4

    def test_plan_round_batch_resolution(self):
        import jax.numpy as jnp
        from repro import compat
        # explicit beats auto; auto resolves from payload size
        assert NB._resolve_round_batch(3, 1 << 30, 15) == 3
        assert NB._resolve_round_batch(None, 128 << 10, 15) == 15
        assert NB._resolve_round_batch(0, 1 << 30, 15) == 1
        # n == 1: degenerate empty schedule pins the batch to 1
        mesh = compat.make_mesh((1,), ("x",))
        eng = ProgressEngine()
        coll = NB.UserCollectives(eng)
        h = coll.allreduce_init(jnp.zeros((2, 8), jnp.float32), mesh, "x",
                                round_batch=3, warmup=False)
        assert h.round_batch == 1
        coll.close()


# ---------------------------------------------------------------------------
# Exactly-once under random drains, with batching in play
# ---------------------------------------------------------------------------

def test_persistent_restart_random_drains():
    """A persistent handle restarted many times under random progress/
    drain interleavings executes every (fused) stage exactly once per
    start."""
    from repro.core import DEFERRED
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng, policy=DEFERRED)
    counts = []

    def stage(s):
        def fn(v):
            counts[-1][s] += 1
            return v + 1
        return fn

    plan = fake_plan([host_schedule([stage(0), stage(1), stage(2)])])
    h = NB.PersistentCollective(coll, plan, warmup=False)
    rng = random.Random(7)
    for trial in range(20):
        counts.append([0, 0, 0])
        req = h.start(float(trial))
        steps = 0
        while not req.is_complete and steps < 10_000:
            op = rng.randrange(3)
            if op == 0:
                eng.progress(coll.stream)
            elif op == 1:
                coll.queue.drain(max_items=rng.randrange(1, 3))
            else:
                eng.progress(coll.stream)
                coll.queue.drain()
            steps += 1
        assert req.value() == trial + 3.0
    assert counts == [[1, 1, 1]] * 20
    coll.close()


# ---------------------------------------------------------------------------
# Satellites: run.py section validation + trend gate
# ---------------------------------------------------------------------------

def test_run_py_unknown_section_errors():
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--sections", "nope", "--json", ""])
    assert "unknown section" in str(exc.value)


def _summary(rev, rows):
    return {"schema": "repro-bench-v1", "git_rev": rev,
            "rows": [{"section": "s", "name": k, "us_per_call": v,
                      "derived": ""} for k, v in rows.items()]}


class TestTrendGate:
    def write(self, tmp_path, prev_rows, cur_rows):
        prev = tmp_path / "prev.json"
        cur = tmp_path / "cur.json"
        prev.write_text(json.dumps(_summary("aaa", prev_rows)))
        cur.write_text(json.dumps(_summary("bbb", cur_rows)))
        return str(prev), str(cur)

    def test_regression_flagged_and_exits_nonzero(self, tmp_path):
        from benchmarks import trend
        prev, cur = self.write(
            tmp_path,
            {"fig7_pending_1": 100.0, "fig14_user_x": 50.0},
            {"fig7_pending_1": 130.0, "fig14_user_x": 50.0})
        summary = tmp_path / "step_summary.md"
        rc = trend.main(["--current", cur, "--previous", prev,
                         "--summary", str(summary)])
        assert rc == 1
        text = summary.read_text()
        assert "regressed" in text and "fig7_pending_1" in text
        assert "+30.0%" in text

    def test_improvement_and_noise_pass(self, tmp_path):
        from benchmarks import trend
        prev, cur = self.write(
            tmp_path,
            {"fig13_cb_1": 100.0, "fig14_user_y": 200.0},
            {"fig13_cb_1": 110.0, "fig14_user_y": 40.0})  # +10%, -80%
        rc = trend.main(["--current", cur, "--previous", prev,
                         "--summary", ""])
        assert rc == 0

    def test_untracked_and_ratio_rows_ignored(self, tmp_path):
        from benchmarks import trend
        prev, cur = self.write(
            tmp_path,
            {"kernel_matmul": 10.0, "fig14_persistent_gain_x": 1.0},
            {"kernel_matmul": 900.0, "fig14_persistent_gain_x": 9.0})
        rc = trend.main(["--current", cur, "--previous", prev,
                         "--summary", ""])
        assert rc == 0                       # neither row is tracked

    def test_new_and_gone_rows_do_not_gate(self, tmp_path):
        from benchmarks import trend
        prev, cur = self.write(tmp_path,
                               {"fig7_old_row": 10.0},
                               {"fig7_new_row": 10.0})
        rc = trend.main(["--current", cur, "--previous", prev,
                         "--summary", ""])
        assert rc == 0

    def test_missing_previous_is_not_an_error(self, tmp_path):
        from benchmarks import trend
        _, cur = self.write(tmp_path, {}, {"fig7_x": 1.0})
        summary = tmp_path / "s.md"
        rc = trend.main(["--current", cur,
                         "--previous", str(tmp_path / "absent.json"),
                         "--summary", str(summary)])
        assert rc == 0
        assert "nothing to compare" in summary.read_text()

    def test_missing_current_errors(self, tmp_path):
        from benchmarks import trend
        rc = trend.main(["--current", str(tmp_path / "absent.json"),
                         "--previous", str(tmp_path / "also_absent.json"),
                         "--summary", ""])
        assert rc == 2
