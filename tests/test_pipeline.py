"""Pipeline parallelism: GPipe fwd equivalence + gradient flow, the
event-driven 1F1B continuation-DAG schedule (bit-identical losses and
grads, engine-stats assertions), and elastic resharding end-to-end
(multi-device subprocesses)."""
import pytest

from repro.distributed.pipeline import (_build_grid, bubble_fraction,
                                        peak_activation_microbatches)
from tests._multidevice import run_with_devices


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 28) - 3 / 31) < 1e-12
    # 1F1B burns the same warmup bubble as GPipe...
    assert bubble_fraction(4, 4, "1f1b") == bubble_fraction(4, 4, "gpipe")
    # ...its win is peak activation memory: min(S, M) stashes, not M
    assert peak_activation_microbatches(4, 16, "gpipe") == 16
    assert peak_activation_microbatches(4, 16, "1f1b") == 4
    assert peak_activation_microbatches(8, 4, "1f1b") == 4
    with pytest.raises(ValueError):
        bubble_fraction(4, 4, "interleaved")
    with pytest.raises(ValueError):
        peak_activation_microbatches(4, 4, "zb-h1")


def test_1f1b_grid_realizes_analytic_bubble():
    """The greedy tick simulation must land exactly on the analytic
    schedule: 2(M+S-1) ticks, 2M cells per stage, peak stash min(S,M)."""
    for S, M in [(1, 4), (2, 4), (2, 8), (3, 5), (4, 4), (4, 8), (4, 16)]:
        g = _build_grid(S, M)
        assert g.ticks == 2 * (M + S - 1), (S, M, g.ticks)
        assert len(g.ops) == 2 * S * M
        measured = 1 - len(g.ops) / (S * g.ticks)
        assert abs(measured - bubble_fraction(S, M, "1f1b")) < 1e-12
        assert g.peak_stash == peak_activation_microbatches(S, M, "1f1b")
        # forward-only grid: the classic M+S-1 tick pipeline
        gf = _build_grid(S, M, forward_only=True)
        assert gf.ticks == M + S - 1


def test_pipeline_forward_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.distributed.pipeline import gpipe
        S, M, mb, D = 4, 6, 2, 16
        mesh = compat.make_mesh((S,), ("stage",))
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        # each stage: x -> tanh(x @ w + b)
        params = {"w": jax.random.normal(ks[0], (S, D, D)) * 0.3,
                  "b": jnp.zeros((S, D))}
        xs = jax.random.normal(ks[1], (M, mb, D))

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        run = jax.jit(gpipe(stage_fn, mesh, "stage", S))
        y = run(params, xs)

        # sequential reference
        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ params["w"][s] + params["b"][s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("PIPE_FWD_OK")
    """, n_devices=4)
    assert "PIPE_FWD_OK" in out


def test_pipeline_gradients_match_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.distributed.pipeline import gpipe
        S, M, mb, D = 4, 4, 2, 8
        mesh = compat.make_mesh((S,), ("stage",))
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        params = {"w": jax.random.normal(ks[0], (S, D, D)) * 0.3}
        xs = jax.random.normal(ks[1], (M, mb, D))

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        run = gpipe(stage_fn, mesh, "stage", S)
        g_pipe = jax.jit(jax.grad(lambda p: jnp.sum(run(p, xs) ** 2)))(params)

        def seq_loss(p):
            y = xs
            for s in range(S):
                y = jnp.tanh(y @ p["w"][s])
            return jnp.sum(y ** 2)

        g_ref = jax.grad(seq_loss)(params)
        np.testing.assert_allclose(np.asarray(g_pipe["w"]),
                                   np.asarray(g_ref["w"]),
                                   atol=1e-4, rtol=1e-4)
        print("PIPE_BWD_OK")
    """, n_devices=4)
    assert "PIPE_BWD_OK" in out


def test_1f1b_bit_identical_and_event_driven():
    """The tentpole acceptance test: on 2- and 4-stage meshes the 1F1B
    DAG's forward is bit-identical to ``gpipe()`` and sequential, its
    loss/grads are bit-identical to sequential per-microbatch
    accumulation over a 5-step trajectory, and the handoffs ran as
    persistent user-space p2p (engine stats: nonzero p2p stream
    completions, executor-issued hops, no polling in the lifecycle —
    the only blocking wait is the caller's, once per step)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import ProgressEngine, ProgressExecutor
        from repro.distributed import pipeline as pl

        M, d, h, mb = 8, 8, 16, 4

        def stage_fn(p, x):
            return x + jnp.tanh(x @ p["w1"]) @ p["w2"]

        def loss_fn(y, t):
            return jnp.mean((y - t) ** 2)

        engine = ProgressEngine()
        ex = ProgressExecutor(engine, num_workers=2).start()
        engine.attach_executor(ex)

        for S in (2, 4):
            k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(S), 4)
            params = {"w1": jax.random.normal(k1, (S, d, h)) * 0.1,
                      "w2": jax.random.normal(k2, (S, h, d)) * 0.1}
            xs = jax.random.normal(k3, (M, mb, d))
            ts = jax.random.normal(k4, (M, mb, d))
            mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
            sched = pl.PipelineSchedule(stage_fn, mesh, "stage", S,
                                        loss_fn=loss_fn, engine=engine,
                                        executor=ex, name=f"p{S}")

            # forward: bitwise vs sequential chain AND the gpipe scan
            def seq_apply(p, xs):
                def one(x):
                    for s in range(S):
                        x = stage_fn(jax.tree.map(lambda a, s=s: a[s], p), x)
                    return x
                return jnp.stack([one(xs[m]) for m in range(M)])

            ys = sched.apply(params, xs, timeout=300)
            assert np.array_equal(np.asarray(ys),
                                  np.asarray(seq_apply(params, xs)))
            gp = pl.gpipe(stage_fn, mesh, "stage", S)
            gys = gp(jax.device_put(params,
                                    NamedSharding(mesh, P("stage"))), xs)
            assert np.array_equal(np.asarray(ys), np.asarray(gys))
            print(f"S={S} FWD_BITWISE_OK")

            # sequential (unpipelined) reference: the SAME jitted
            # per-stage kernels the schedule compiles (fwd / bwd /
            # last_bwd, identical jaxpr structure), run one microbatch
            # at a time with per-stage accumulation in the same m order
            # and the same 1/M seed — only the schedule differs, so the
            # comparison is bitwise
            def fwd(p1, x1):
                p0 = jax.tree.map(lambda a: a[0], p1)
                return stage_fn(p0, x1[0])[None]

            def bwd(p1, x1, dy1, acc):
                p0 = jax.tree.map(lambda a: a[0], p1)
                _, pull = jax.vjp(stage_fn, p0, x1[0])
                dp, dx = pull(dy1[0])
                acc = jax.tree.map(lambda a, d: a + d[None], acc, dp)
                return dx[None], acc

            def last_bwd(p1, x1, t1, scale, acc):
                p0 = jax.tree.map(lambda a: a[0], p1)
                def head(pp, xx):
                    return loss_fn(stage_fn(pp, xx), t1[0])
                loss, pull = jax.vjp(head, p0, x1[0])
                dp, dx = pull(scale)
                acc = jax.tree.map(lambda a, d: a + d[None], acc, dp)
                return loss, dx[None], acc

            f_ = jax.jit(fwd)
            b_ = jax.jit(bwd, donate_argnums=(3,))
            lb_ = jax.jit(last_bwd, donate_argnums=(4,))

            def seq_step(p, xs, ts):
                scale = jnp.float32(1.0 / M)
                pst = [jax.tree.map(lambda a, s=s: a[s:s+1], p)
                       for s in range(S)]
                acc = [jax.tree.map(jnp.zeros_like, q) for q in pst]
                losses = []
                for m in range(M):
                    x = xs[m:m+1]; stash = []
                    for s in range(S - 1):
                        stash.append(x); x = f_(pst[s], x)
                    lm, dx, acc[S-1] = lb_(pst[S-1], x, ts[m:m+1],
                                           scale, acc[S-1])
                    losses.append(lm)
                    for s in range(S-2, -1, -1):
                        dx, acc[s] = b_(pst[s], stash[s], dx, acc[s])
                total = losses[0]
                for lm in losses[1:]:
                    total = total + lm
                g = jax.tree.map(lambda *a: jnp.concatenate(a), *acc)
                return total * scale, g

            # the gpipe() reference trajectory (AD through the scan —
            # same math, different fusion, so float-tolerance not bits)
            def gp_loss(p, xs, ts):
                ys = gp(p, xs)
                per = jnp.stack([loss_fn(ys[m], ts[m]) for m in range(M)])
                return jnp.mean(per)
            gvg = jax.jit(jax.value_and_grad(gp_loss))

            # 5-step SGD trajectory: loss AND grads bit-identical to
            # sequential, loss tracking gpipe's own evolved trajectory
            lr = 0.05
            p_dag, p_seq = params, params
            p_gp = jax.device_put(params,
                                  NamedSharding(mesh, P("stage")))
            for step in range(5):
                loss, grads = sched.step(p_dag, xs, ts, timeout=300)
                sl, sg = seq_step(p_seq, xs, ts)
                assert np.asarray(loss).tobytes() == \\
                    np.asarray(sl).tobytes(), (step, float(loss), float(sl))
                for kk in ("w1", "w2"):
                    assert np.array_equal(np.asarray(grads[kk]),
                                          np.asarray(sg[kk])), (step, kk)
                gl, gg = gvg(p_gp, xs, ts)
                np.testing.assert_allclose(float(loss), float(gl),
                                           rtol=0, atol=1e-5)
                p_dag = jax.tree.map(lambda p, g: p - lr * g, p_dag, grads)
                p_seq = jax.tree.map(lambda p, g: p - lr * g, p_seq, sg)
                p_gp = jax.tree.map(lambda p, g: p - lr * g, p_gp, gg)
            print(f"S={S} TRAJECTORY_BITWISE_OK")

            st = sched.stats()
            assert st["p2p_stream_completions"] > 0, st
            assert st["hop_starts"]["f"] > 0 and st["hop_starts"]["b"] > 0
            assert st["p2p_issued"] == st["p2p_completed"] > 0, st
            # zero polling loops in the request lifecycle: the DAG
            # completes purely through continuations; the only blocking
            # wait is the caller's, one per apply/step call
            assert st["blocking_waits"] == 6, st
            # hops were issued by executor workers (persistent
            # user-space requests, executor-driven starts)
            for chan in sched._chan.values():
                inner = chan.persistent.active
                assert inner is not None and \\
                    inner.issue_thread in ex.worker_thread_idents(), \\
                    (inner, ex.worker_thread_idents())
            print(f"S={S} STATS_OK")
            sched.close()

        ex.shutdown(drain=True, timeout=120)
        print("ALL_OK")
    """, n_devices=4)
    for s in (2, 4):
        assert f"S={s} FWD_BITWISE_OK" in out
        assert f"S={s} TRAJECTORY_BITWISE_OK" in out
        assert f"S={s} STATS_OK" in out
    assert "ALL_OK" in out


def test_elastic_reshard_restore_end_to_end():
    """Save on an 8-device (4,2) mesh, 'lose' half the fleet, restore
    resharded onto (2,2) — values identical, shardings valid."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro import compat
        from repro.core import ProgressEngine
        from repro.train.checkpoint import AsyncCheckpointer
        from repro.distributed.elastic import plan_mesh, reshard_restore
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L

        mesh8 = compat.make_mesh((4, 2), ("data", "model"))
        spec_tree_axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
                "b": jnp.ones((8,))}
        eng = ProgressEngine()
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d, eng)
            ck.save_blocking(5, tree)
            # surviving fleet: 4 devices
            shape, axes = plan_mesh(4, prefer_model=2)
            assert shape == (2, 2), shape
            mesh4 = make_mesh(shape, axes)
            restored = reshard_restore(ck, 5, tree, spec_tree_axes, mesh4)
            np.testing.assert_allclose(np.asarray(restored["w"]),
                                       np.asarray(tree["w"]))
            sh = restored["w"].sharding
            assert sh.mesh.shape == {"data": 2, "model": 2}
        print("ELASTIC_OK")
    """, n_devices=8)
    assert "ELASTIC_OK" in out
