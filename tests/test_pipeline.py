"""GPipe pipeline parallelism: fwd equivalence + gradient flow + elastic
resharding end-to-end (multi-device subprocess)."""
from repro.distributed.pipeline import bubble_fraction
from tests._multidevice import run_with_devices


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 28) - 3 / 31) < 1e-12


def test_pipeline_forward_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.distributed.pipeline import gpipe
        S, M, mb, D = 4, 6, 2, 16
        mesh = compat.make_mesh((S,), ("stage",))
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        # each stage: x -> tanh(x @ w + b)
        params = {"w": jax.random.normal(ks[0], (S, D, D)) * 0.3,
                  "b": jnp.zeros((S, D))}
        xs = jax.random.normal(ks[1], (M, mb, D))

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        run = jax.jit(gpipe(stage_fn, mesh, "stage", S))
        y = run(params, xs)

        # sequential reference
        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ params["w"][s] + params["b"][s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("PIPE_FWD_OK")
    """, n_devices=4)
    assert "PIPE_FWD_OK" in out


def test_pipeline_gradients_match_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.distributed.pipeline import gpipe
        S, M, mb, D = 4, 4, 2, 8
        mesh = compat.make_mesh((S,), ("stage",))
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        params = {"w": jax.random.normal(ks[0], (S, D, D)) * 0.3}
        xs = jax.random.normal(ks[1], (M, mb, D))

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        run = gpipe(stage_fn, mesh, "stage", S)
        g_pipe = jax.jit(jax.grad(lambda p: jnp.sum(run(p, xs) ** 2)))(params)

        def seq_loss(p):
            y = xs
            for s in range(S):
                y = jnp.tanh(y @ p["w"][s])
            return jnp.sum(y ** 2)

        g_ref = jax.grad(seq_loss)(params)
        np.testing.assert_allclose(np.asarray(g_pipe["w"]),
                                   np.asarray(g_ref["w"]),
                                   atol=1e-4, rtol=1e-4)
        print("PIPE_BWD_OK")
    """, n_devices=4)
    assert "PIPE_BWD_OK" in out


def test_elastic_reshard_restore_end_to_end():
    """Save on an 8-device (4,2) mesh, 'lose' half the fleet, restore
    resharded onto (2,2) — values identical, shardings valid."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro import compat
        from repro.core import ProgressEngine
        from repro.train.checkpoint import AsyncCheckpointer
        from repro.distributed.elastic import plan_mesh, reshard_restore
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L

        mesh8 = compat.make_mesh((4, 2), ("data", "model"))
        spec_tree_axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
                "b": jnp.ones((8,))}
        eng = ProgressEngine()
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d, eng)
            ck.save_blocking(5, tree)
            # surviving fleet: 4 devices
            shape, axes = plan_mesh(4, prefer_model=2)
            assert shape == (2, 2), shape
            mesh4 = make_mesh(shape, axes)
            restored = reshard_restore(ck, 5, tree, spec_tree_axes, mesh4)
            np.testing.assert_allclose(np.asarray(restored["w"]),
                                       np.asarray(tree["w"]))
            sh = restored["w"].sharding
            assert sh.mesh.shape == {"data": 2, "model": 2}
        print("ELASTIC_OK")
    """, n_devices=8)
    assert "ELASTIC_OK" in out
