"""Static progress-safety lint (PR 10 tentpole).

Fixture-based true positives for every rule family (PL001-PL004),
negative fixtures for the documented escape hatches (``timeout=0``,
``blocking=False``, rebinding a donated buffer), allowlist hygiene
(entries without a written justification are rejected), and the
tree-clean gate the CI job enforces: linting today's ``src/repro``
with the shipped allowlist yields zero non-allowlisted findings.
"""
import os
import textwrap

import pytest

from repro.analysis import progress_lint as PL


def lint(src):
    return PL.lint_source(textwrap.dedent(src))


def rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# PL001 — blocking call reachable from a continuation body
# ---------------------------------------------------------------------------

class TestPL001:
    def test_direct_wait_in_attached_method(self):
        fs = lint("""
            class Engine:
                def _on_done(self, req):
                    req.wait()

                def run(self, q, req):
                    q.attach(req, self._on_done)
        """)
        assert rules(fs) == ["PL001"]
        assert fs[0].qual == "Engine._on_done"
        assert "wait" in fs[0].message

    def test_transitive_sleep_through_helper(self):
        fs = lint("""
            import time

            class Engine:
                def _helper(self):
                    time.sleep(1.0)

                def _on_err(self, req):
                    self._helper()

                def run(self, q, req):
                    q.attach(req, lambda r: None, on_error=self._on_err)
        """)
        assert rules(fs) == ["PL001"]
        assert "sleep" in fs[0].message
        # the chain through _helper is spelled out for the reader
        assert "_helper" in fs[0].message

    def test_lambda_result_and_subsystem_poll(self):
        fs = lint("""
            def setup(engine, q, req, fut):
                q.then(req, lambda r: fut.result())

            def register(engine, poller):
                engine.register_subsystem("io", poller)

            def poller():
                import threading
                cond = threading.Condition()
                with cond:
                    cond.wait()
        """)
        assert rules(fs) == ["PL001", "PL001"]
        msgs = " ".join(f.message for f in fs)
        assert "result" in msgs and "Condition" in msgs or "wait" in msgs

    def test_nonblocking_forms_not_flagged(self):
        fs = lint("""
            def setup(q, req, lock):
                q.attach(req, lambda r: r.wait(timeout=0))
                q.attach(req, lambda r: lock.acquire(blocking=False))
                q.attach(req, lambda r: ", ".join(["a", "b"]))
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# PL002 — handle lifecycle (declared machine, statically visible order)
# ---------------------------------------------------------------------------

class TestPL002:
    def test_double_start(self):
        fs = lint("""
            def f(coll, mesh, x):
                h = coll.allreduce_init(x, mesh, "i")
                h.start(x)
                h.start(x)
        """)
        assert rules(fs) == ["PL002"]
        assert "double-start" in fs[0].message

    def test_start_after_invalidate_without_rebuild(self):
        fs = lint("""
            def f(coll, mesh, epoch, x):
                h = coll.reduce_scatter_init(x, mesh, "i")
                epoch.invalidate(survivors=1)
                h.start(x)
        """)
        assert rules(fs) == ["PL002"]
        assert "start-after-invalidate-without-rebuild" in fs[0].message

    def test_use_after_close(self):
        fs = lint("""
            def f(coll, mesh, x):
                h = coll.allgather_init(x, mesh, "i")
                h.close()
                h.start(x)
        """)
        assert rules(fs) == ["PL002"]
        assert "use-after-close" in fs[0].message

    def test_wait_without_start(self):
        fs = lint("""
            def f(coll, mesh, x):
                h = coll.allreduce_init(x, mesh, "i")
                h.active.wait()
        """)
        assert rules(fs) == ["PL002"]
        assert "wait-without-start" in fs[0].message

    def test_legal_lifecycle_clean(self):
        fs = lint("""
            def f(coll, mesh, x):
                h = coll.allreduce_init(x, mesh, "i")
                r = h.start(x)
                r.wait()
                h.start(x)
                h.cancel()
                h.rebuild(mesh)
                h.close()
                h.close()
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# PL003 — lock-order inversion across function bodies
# ---------------------------------------------------------------------------

class TestPL003:
    def test_inverted_nesting_reported_once(self):
        fs = lint("""
            class Engine:
                def forward(self, q):
                    with self._lock:
                        with q._qlock:
                            pass

                def backward(self, q):
                    with q._qlock:
                        with self._lock:
                            pass
        """)
        assert rules(fs) == ["PL003"]
        assert "Engine._lock" in fs[0].message

    def test_consistent_order_clean(self):
        fs = lint("""
            class Engine:
                def a(self, q):
                    with self._lock:
                        with q._qlock:
                            pass

                def b(self, q):
                    with self._lock:
                        with q._qlock:
                            pass
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# PL004 — donated/aliased buffer reused after the donating call
# ---------------------------------------------------------------------------

class TestPL004:
    def test_donated_carry_reused(self):
        fs = lint("""
            import jax

            def builder(carry):
                step = jax.jit(lambda c: c + 1, donate_argnums=(0,))
                out = step(carry)
                return carry + out
        """)
        assert rules(fs) == ["PL004"]
        assert "carry" in fs[0].message

    def test_rebinding_kills_donation(self):
        fs = lint("""
            import jax

            def builder(carry):
                step = jax.jit(lambda c: c + 1, donate_argnums=(0,))
                carry = step(carry)
                return carry
        """)
        assert fs == []


# ---------------------------------------------------------------------------
# Allowlist hygiene + tree-clean gate
# ---------------------------------------------------------------------------

class TestAllowlist:
    def test_shipped_allowlist_is_well_formed(self):
        entries = PL.load_allowlist()
        for e in entries:
            assert e["rule"] in PL.RULES
            assert e["why"].strip(), e

    def test_entry_without_justification_rejected(self, monkeypatch, tmp_path):
        bad = tmp_path / "progress_lint_allowlist.py"
        bad.write_text("ALLOWLIST = ({'rule': 'PL001', 'path': 'x.py',"
                       " 'qual': 'f', 'why': ''},)\n")
        monkeypatch.setattr(PL, "_HERE", str(tmp_path))
        with pytest.raises(ValueError, match="justification"):
            PL.load_allowlist()

    def test_allowlist_matches_by_suffix_and_qual(self):
        fs = lint("""
            def poll(fut):
                return fut.result()
        """)
        assert fs == []  # not a continuation site: nothing to allow

    def test_tree_is_clean_under_allowlist(self):
        files = PL.collect_paths(PL._PKG_ROOT)
        modules = [m for m in (PL.parse_module(p) for p in files)
                   if m is not None]
        findings = PL.lint_modules(modules)
        PL.apply_allowlist(findings, PL.load_allowlist())
        flagged = [f for f in findings if not f.allowed]
        assert flagged == [], PL.format_findings(flagged)

    def test_strict_cli_exits_zero_on_tree(self, capsys):
        assert PL.main(["--strict"]) == 0
        out = capsys.readouterr().out
        assert "progress_lint" in out

    def test_lifecycle_tables_shared_with_runtime(self):
        trans, viol = PL._lifecycle_tables()
        from repro.core import debug
        assert trans == debug.LIFECYCLE_TRANSITIONS
        assert viol == debug.LIFECYCLE_VIOLATIONS
