"""Property-based tests (hypothesis) on system invariants."""
import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: pip install hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis import hlo as hlo_mod
from repro.collectives.compression import dequantize_int8, quantize_int8
from repro.core import (DEFERRED, DONE, INLINE, NOPROGRESS, CompletionCounter,
                        ContinuationQueue, ProgressEngine, Request)
from repro.kernels import ref
from repro.sharding import DEFAULT_RULES, resolve_spec
from jax.sharding import PartitionSpec as P

SETTINGS = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Progress engine invariants
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=30))
def test_engine_all_tasks_complete_exactly_once(poll_counts):
    """Every task completes exactly once regardless of poll cadence."""
    eng = ProgressEngine()
    completions = []

    for i, n in enumerate(poll_counts):
        state = {"left": n, "id": i}

        def poll(thing, state=state):
            if state["left"] <= 0:
                completions.append(state["id"])
                return DONE
            state["left"] -= 1
            return NOPROGRESS

        eng.async_start(poll, state)
    for _ in range(max(poll_counts) + 2):
        eng.progress()
    assert sorted(completions) == list(range(len(poll_counts)))
    assert eng.default_stream.pending == 0


@SETTINGS
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=20))
def test_engine_spawn_depth(depth, width):
    """Spawned chains of any depth eventually drain."""
    eng = ProgressEngine()
    seen = []

    def make(level):
        def poll(thing):
            seen.append(level)
            if level < depth:
                thing.spawn(make(level + 1), None)
            return DONE
        return poll

    for _ in range(width):
        eng.async_start(make(1), None)
    eng.drain(timeout=10)
    assert len(seen) == depth * width


# ---------------------------------------------------------------------------
# Wait-set / completion-counter / continuation invariants
# ---------------------------------------------------------------------------

def _counting_task(req, polls_left):
    """Task completing ``req`` after ``polls_left`` NOPROGRESS sweeps."""
    state = {"left": polls_left}

    def poll(thing):
        if state["left"] <= 0:
            req.complete()
            return DONE
        state["left"] -= 1
        return NOPROGRESS
    return poll


@SETTINGS
@given(st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                max_size=12),
       st.integers(min_value=1, max_value=12))
def test_wait_sets_return_only_completed_requests(poll_counts, min_count):
    """wait_any/wait_some only ever report requests that ARE complete,
    with no duplicates, regardless of completion cadence."""
    eng = ProgressEngine()
    reqs = []
    for n in poll_counts:
        r = Request()
        eng.async_start(_counting_task(r, n))
        reqs.append(r)
    idx, winner = eng.wait_any(reqs, timeout=10)
    assert winner is reqs[idx] and winner.is_complete
    k = min(min_count, len(reqs))
    done_idx = eng.wait_some(reqs, min_count=k, timeout=10)
    assert len(done_idx) >= k
    assert len(set(done_idx)) == len(done_idx)          # no duplicates
    assert all(reqs[i].is_complete for i in done_idx)   # only completed
    eng.drain(timeout=10)


@SETTINGS
@given(st.lists(st.booleans(), min_size=1, max_size=20), st.data())
def test_completion_counter_never_overshoots(outcomes, data):
    """completed <= total and remaining >= 0 at every point of any
    completion order; failures still count as completions."""
    reqs = [Request() for _ in outcomes]
    cc = CompletionCounter(reqs)
    order = data.draw(st.permutations(range(len(reqs))))
    done = 0
    for i in order:
        assert cc.completed == done and cc.remaining == len(reqs) - done
        if outcomes[i]:
            reqs[i].complete(i)
        else:
            reqs[i].fail(RuntimeError(f"r{i}"))
        done += 1
        assert 0 <= cc.completed <= cc.total
        assert cc.completed == done
        assert cc.remaining >= 0
    assert cc.is_complete
    assert len(cc.failed) == sum(1 for ok in outcomes if not ok)


@SETTINGS
@given(st.integers(min_value=1, max_value=16),
       st.sampled_from([INLINE, DEFERRED]),
       st.data())
def test_continuations_fire_exactly_once_any_order(n, policy, data):
    """Each attached continuation fires exactly once under an arbitrary
    completion order interleaved with progress sweeps and drains."""
    eng = ProgressEngine()
    q = ContinuationQueue(eng, policy=policy)
    counts = [0] * n
    reqs = [Request() for _ in range(n)]
    for i, r in enumerate(reqs):
        q.attach(r, lambda rr, i=i: counts.__setitem__(i, counts[i] + 1))
    order = data.draw(st.permutations(range(n)))
    for j, i in enumerate(order):
        reqs[i].complete(i)
        if j % 2 == 0:                    # interleave detection + drain
            eng.progress()
            q.drain()
    for _ in range(3):                    # settle stragglers
        eng.progress()
        q.drain()
    assert counts == [1] * n
    assert q.executed == n and q.enqueued == n
    assert q.pending == 0 and q.ready == 0
    assert eng.default_stream.pending == 0   # detection task retired


# ---------------------------------------------------------------------------
# Online softmax == softmax (the flash invariant)
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.integers(min_value=1, max_value=4),      # chunks
       st.integers(min_value=8, max_value=32),     # chunk size
       st.integers(min_value=1, max_value=4))      # rows
def test_online_softmax_equals_softmax(n_chunks, chunk, rows):
    rng = np.random.RandomState(n_chunks * 100 + chunk)
    s = rng.randn(rows, n_chunks * chunk).astype(np.float32) * 5
    # online pass
    m = np.full((rows, 1), -1e30, np.float32)
    l = np.zeros((rows, 1), np.float32)
    acc = np.zeros((rows, 1), np.float32)
    v = rng.randn(rows, n_chunks * chunk, 1).astype(np.float32)
    for i in range(n_chunks):
        blk = s[:, i * chunk:(i + 1) * chunk]
        vb = v[:, i * chunk:(i + 1) * chunk, 0]
        m_new = np.maximum(m, blk.max(-1, keepdims=True))
        p = np.exp(blk - m_new)
        corr = np.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        acc = acc * corr + (p * vb).sum(-1, keepdims=True)
        m = m_new
    online = acc / l
    # reference
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    expected = (p * v[..., 0]).sum(-1, keepdims=True)
    np.testing.assert_allclose(online, expected, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Quantization invariants
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.integers(min_value=1, max_value=2048),
       st.floats(min_value=0.01, max_value=100.0))
def test_quantize_roundtrip_bounded(n, scale):
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * scale)
    q, s = quantize_int8(x, block=128)
    xr = dequantize_int8(q, s, n)
    # error per element bounded by half a bin (= scale value of its block)
    per_block_bin = np.repeat(np.asarray(s).reshape(-1), 128)[:n]
    assert np.all(np.abs(np.asarray(xr - x)) <= per_block_bin * 0.5 + 1e-6)


@SETTINGS
@given(st.integers(min_value=2, max_value=512))
def test_quantize_idempotent(n):
    """Quantizing already-quantized data is lossless."""
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    q, s = quantize_int8(x, block=64)
    xr = dequantize_int8(q, s, n)
    q2, s2 = quantize_int8(xr, block=64)
    xr2 = dequantize_int8(q2, s2, n)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xr2), atol=1e-6)


# ---------------------------------------------------------------------------
# Sharding rule invariants
# ---------------------------------------------------------------------------

_mesh = None


def _get_mesh():
    global _mesh
    if _mesh is None:
        _mesh = compat.make_mesh((1, 1), ("data", "model"))
    return _mesh


@SETTINGS
@given(st.lists(st.sampled_from(sorted(DEFAULT_RULES)), min_size=1, max_size=4),
       st.lists(st.integers(min_value=1, max_value=4096), min_size=1, max_size=4))
def test_resolve_spec_never_assigns_duplicate_axes(axes, dims):
    n = min(len(axes), len(dims))
    spec = resolve_spec(tuple(axes[:n]), tuple(dims[:n]), _get_mesh())
    used = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


@SETTINGS
@given(st.sampled_from(sorted(DEFAULT_RULES)),
       st.integers(min_value=1, max_value=1000))
def test_resolve_spec_divisibility(axis, dim):
    """A sharded dim is always divisible by the assigned axis product."""
    import math
    mesh = compat.make_mesh((2, 4), ("data", "model")) \
        if len(jax.devices()) >= 8 else _get_mesh()
    spec = resolve_spec((axis,), (dim,), mesh)
    if spec and spec[0]:
        parts = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
        size = math.prod(mesh.shape[a] for a in parts)
        assert dim % size == 0


# ---------------------------------------------------------------------------
# HLO parser robustness
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.integers(min_value=1, max_value=9),
       st.integers(min_value=8, max_value=64))
def test_hlo_flops_scale_with_trip_count(layers, width):
    """Parsed FLOPs must scale linearly with scan length."""
    def model(x, ws):
        x, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return x.sum()

    x = jax.ShapeDtypeStruct((4, width), jnp.float32)
    ws = jax.ShapeDtypeStruct((layers, width, width), jnp.float32)
    txt = jax.jit(model).lower(x, ws).compile().as_text()
    res = hlo_mod.analyze(txt)
    dot_flops = 2 * 4 * width * width * layers
    assert res["flops"] >= dot_flops
    assert res["flops"] <= dot_flops * 2.5 + 10000
