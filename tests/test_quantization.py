"""int8 weight quantization for serving: roundtrip + model-level checks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.serve.quantization import (
    QuantizedTensor, dequantize_tree, quantize_tree, quantized_shapes)
from tests.conftest import reduce_cfg


def test_roundtrip_error_bounded(rng):
    w = jax.random.normal(rng, (256, 512)) * 0.3
    qt = quantize_tree({"w": w}, min_size=1)["w"]
    assert isinstance(qt, QuantizedTensor)
    assert qt.q.dtype == jnp.int8
    back = dequantize_tree({"w": qt}, jnp.float32)["w"]
    # per-channel symmetric int8: error ≤ scale/2 per element
    scale = np.asarray(qt.scale)
    err = np.abs(np.asarray(back) - np.asarray(w))
    assert np.all(err <= scale * 0.5 + 1e-7)


def test_small_and_1d_leaves_untouched(rng):
    tree = {"big": jax.random.normal(rng, (512, 512)),
            "small": jax.random.normal(rng, (4, 4)),
            "vec": jnp.ones((1000,)),
            "step": jnp.zeros((), jnp.int32)}
    q = quantize_tree(tree, min_size=1 << 10)
    assert isinstance(q["big"], QuantizedTensor)
    assert not isinstance(q["small"], QuantizedTensor)
    assert not isinstance(q["vec"], QuantizedTensor)
    assert q["step"].dtype == jnp.int32


def test_decode_logits_close_to_fp(rng):
    """Quantized-weight decode ranks tokens ~like the fp model."""
    cfg = reduce_cfg(get_config("qwen2-0.5b"))
    params = registry.init_params(cfg, rng)
    qparams = quantize_tree(params, min_size=1 << 10)
    dq = dequantize_tree(qparams, jnp.dtype(cfg.dtype))
    B = 2
    cache = registry.init_cache(cfg, B, 16)
    toks = jnp.ones((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    lf, _ = registry.decode_step(params, cfg, cache, toks, pos)
    lq, _ = registry.decode_step(dq, cfg, cache, toks, pos)
    a, b = np.asarray(lf[:, 0], np.float32), np.asarray(lq[:, 0], np.float32)
    # correlation of logits is the robust closeness metric for int8
    for i in range(B):
        corr = np.corrcoef(a[i], b[i])[0, 1]
        assert corr > 0.99, corr
    assert np.argmax(a[0]) == np.argmax(b[0])


def test_int8_kv_cache_decode_tracks_bf16(rng):
    """Multi-step decode with int8 KV cache: logits corr > 0.999 and
    identical greedy tokens vs the bf16 cache."""
    cfg = reduce_cfg(get_config("qwen2-0.5b"))
    cfg8 = cfg.with_overrides(kv_cache_dtype="int8")
    params = registry.init_params(cfg, rng)
    B, S = 2, 16
    c16 = registry.init_cache(cfg, B, S)
    c8 = registry.init_cache(cfg8, B, S)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    toks = jnp.array([[3], [7]], jnp.int32)
    for t in range(5):
        pos = jnp.full((B,), t, jnp.int32)
        l16, c16 = registry.decode_step(params, cfg, c16, toks, pos)
        l8, c8 = registry.decode_step(params, cfg8, c8, toks, pos)
        a = np.asarray(l16[:, 0], np.float32)
        b = np.asarray(l8[:, 0], np.float32)
        assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999
        assert np.array_equal(a.argmax(-1), b.argmax(-1))
        toks = jnp.asarray(a.argmax(-1))[:, None].astype(jnp.int32)


def test_quantized_shapes_structure():
    cfg = reduce_cfg(get_config("smollm-360m"))
    shapes = registry.param_shapes(cfg)
    qshapes = quantized_shapes(shapes, min_size=1 << 10)
    n_q = sum(isinstance(x, QuantizedTensor)
              for x in jax.tree.leaves(
                  qshapes, is_leaf=lambda x: isinstance(x, QuantizedTensor)))
    assert n_q > 0
    # every quantized leaf pairs int8 data with f32 scales
    for leaf in jax.tree.leaves(qshapes,
                                is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            assert leaf.q.dtype == jnp.int8
            assert leaf.scale.dtype == jnp.float32
            assert leaf.scale.shape[-1] == 1
