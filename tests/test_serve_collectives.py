"""Serve-side persistent collectives + executor-driven starts (+ the
overlap/serve correctness fixes that ride along).

Sharded-serve equivalence runs in multi-device subprocesses (1/2/4
devices); executor-driven start mechanics, latency bookkeeping and
bucketing fixes run in-process.
"""
import threading
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import trend
from repro.collectives import nonblocking as NB
from repro.collectives.overlap import bucket_tree
from repro.configs import get_config
from repro.core import ProgressEngine, ProgressExecutor
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine
from tests._multidevice import run_with_devices
from tests.conftest import reduce_cfg


# ---------------------------------------------------------------------------
# Sharded serve: user backend token streams == native-sharded (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_sharded_serve_user_matches_native(n_devices):
    """Acceptance: decode with --collective-backend user on a model axis
    produces token streams identical to the native-sharded path (both
    consume the same partial-logits program; only the gather differs)."""
    out = run_with_devices(f"""
        import jax, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.models import registry
        from repro.serve.engine import GenRequest, ServeEngine

        n = {n_devices}
        cfg = get_config('qwen2-0.5b').with_overrides(
            num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
            num_kv_heads=2, head_dim=16, remat_policy='none')
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        mesh = compat.make_mesh((n,), ('model',))

        def serve(backend, mesh):
            eng = ProgressEngine()
            srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64,
                              mesh=mesh, collective_backend=backend,
                              collective_chunks=2)
            reqs = [GenRequest(f'r{{i}}', np.array([i + 1, i + 2], np.int32),
                               max_new_tokens=4) for i in range(6)]
            dones = [srv.submit(r) for r in reqs]
            srv.run_until_idle(timeout=300)
            toks = [d.value() for d in dones]
            assert srv._ag_handle is None or srv._ag_handle.starts == srv.steps
            srv.close(timeout=60)
            return toks

        native = serve('native', mesh)
        user = serve('user', mesh)
        assert native == user, (native, user)
        assert all(len(t) == 4 for t in user)
        if n > 1:    # vocab not divisible by the model axis: eager error
            bad = cfg.with_overrides(vocab_size=63)
            try:
                ServeEngine(bad, registry.init_params(bad,
                            jax.random.PRNGKey(0)), ProgressEngine(),
                            batch_slots=2, max_seq=32, mesh=mesh)
                raise AssertionError('divisibility not validated')
            except ValueError as e:
                assert 'divisible' in str(e)
        print('SHARDED_SERVE_EQUIV_OK')
    """, n_devices=n_devices)
    assert "SHARDED_SERVE_EQUIV_OK" in out


def test_sharded_serve_on_executor_matches_caller_driven():
    """Executor-adopted serve-collective stream (executor-driven gather
    starts) produces the same tokens as the caller-driven bridge."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro import compat
        from repro.configs import get_config
        from repro.core import ProgressEngine, ProgressExecutor
        from repro.models import registry
        from repro.serve.engine import GenRequest, ServeEngine

        cfg = get_config('qwen2-0.5b').with_overrides(
            num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
            num_kv_heads=2, head_dim=16, remat_policy='none')
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        mesh = compat.make_mesh((2,), ('model',))

        def serve(workers, start=True):
            eng = ProgressEngine()
            ex = None
            if workers:
                ex = ProgressExecutor(eng, workers, steal=False)
                if start:
                    ex.start()
            srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=64,
                              mesh=mesh, collective_backend='user',
                              executor=ex)
            r = GenRequest('a', np.array([5, 6], np.int32), max_new_tokens=4)
            d = srv.submit(r)
            srv.run_until_idle(timeout=300)
            srv.close(timeout=60)
            if ex is not None and ex.running:
                ex.shutdown(drain=True, timeout=60)
            return d.value()

        assert serve(0) == serve(2)
        # regression: executor attached but never started must degrade
        # to inline progress of ALL serve streams (incl. the collective
        # stream driving the gather rounds), not hang to TimeoutError
        assert serve(2, start=False) == serve(0)
        print('EXEC_SERVE_EQUIV_OK')
    """, n_devices=2)
    assert "EXEC_SERVE_EQUIV_OK" in out


def test_sharded_serve_rejects_bad_configs(rng):
    # (vocab divisibility needs a >1 model axis — validated in the
    # 2/4-device subprocess above)
    from repro import compat
    mesh = compat.make_mesh((1,), ("model",))
    cfg = reduce_cfg(get_config("qwen2-0.5b"))
    params = registry.init_params(cfg, rng)
    with pytest.raises(ValueError, match="axis"):
        ServeEngine(cfg, params, ProgressEngine(), batch_slots=2,
                    max_seq=32, mesh=mesh, model_axis="nope")
    with pytest.raises(ValueError, match="backend"):
        ServeEngine(cfg, params, ProgressEngine(), batch_slots=2,
                    max_seq=32, collective_backend="bogus")


# ---------------------------------------------------------------------------
# Executor-driven persistent starts (in-process, fake host plans)
# ---------------------------------------------------------------------------

def host_schedule(fns):
    sched = NB._Schedule(tuple(fns))
    return types.SimpleNamespace(num_rounds=len(fns),
                                 compiled=lambda b: sched)


def fake_plan(schedules, split=None, join=None):
    return NB._Plan("allreduce", "ring", None, None, None, None,
                    schedules, split or (lambda x: [x]),
                    join or NB._first, 0, 1)


class TestExecutorDrivenStart:
    def test_start_dispatches_on_worker_not_caller(self):
        """Acceptance: start() on an executor-adopted stream returns
        without dispatching round 0 on the calling thread — the worker
        that owns the collective stream issues it."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, 1, steal=False)
        coll = NB.UserCollectives(eng, executor=ex)
        h = NB.PersistentCollective(
            coll, fake_plan([host_schedule([lambda v: v + 1,
                                            lambda v: v * 10])]),
            warmup=False)
        ex.start()
        try:
            main = threading.get_ident()
            req = h.start(2.0)
            assert req.wait(timeout=30) == 30.0
            assert req.issue_thread is not None
            assert req.issue_thread != main
            assert req.issue_thread in ex.worker_thread_idents()
        finally:
            ex.shutdown(drain=True, timeout=30)
            coll.close()

    def test_start_falls_back_to_caller_thread(self):
        """No running executor: round 0 dispatches on the start() caller
        (and an executor constructed but never started does not defer)."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, 1, steal=False)     # never started
        coll = NB.UserCollectives(eng, executor=ex)
        h = NB.PersistentCollective(
            coll, fake_plan([host_schedule([lambda v: v + 1])]),
            warmup=False)
        req = h.start(1.0)
        assert req.issue_thread == threading.get_ident()
        assert req.wait(timeout=30) == 2.0
        coll.close()

    def test_deferred_split_failure_fails_request(self):
        """A split that raises inside the worker-issued launch fails the
        request (observable via wait), never the worker thread."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, 1, steal=False)
        coll = NB.UserCollectives(eng, executor=ex)

        def bad_split(x):
            raise RuntimeError("split boom")

        h = NB.PersistentCollective(
            coll, fake_plan([host_schedule([lambda v: v])],
                            split=bad_split),
            warmup=False)
        ex.start()
        try:
            req = h.start(1.0)
            with pytest.raises(RuntimeError, match="split boom"):
                req.wait(timeout=30)
            assert req.failed
            # handle restartable after the failed deferred start
            h.plan.split = lambda x: [x]
            assert h.start(3.0).wait(timeout=30) == 3.0
        finally:
            ex.shutdown(drain=True, timeout=30)
            coll.close()

    def test_executor_shutdown_between_start_and_wait(self):
        """The issue task survives executor shutdown: wait() falls back
        to inline progress and still completes the collective."""
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, 1, steal=False)
        coll = NB.UserCollectives(eng, executor=ex)
        h = NB.PersistentCollective(
            coll, fake_plan([host_schedule([lambda v: v * 2])]),
            warmup=False)
        ex.start()
        req = h.start(4.0)
        ex.shutdown(drain=False, timeout=30)   # workers gone, task queued
        assert req.wait(timeout=30) == 8.0
        coll.close()


# ---------------------------------------------------------------------------
# Serve latency fields (TTFT exactly once; finished_at on both paths)
# ---------------------------------------------------------------------------

class CountingGenRequest(GenRequest):
    """Counts first_token_at stamps (None -> value transitions)."""

    def __setattr__(self, key, value):
        if key == "first_token_at" and value is not None:
            object.__setattr__(self, "_ttft_stamps",
                               getattr(self, "_ttft_stamps", 0) + 1)
        object.__setattr__(self, key, value)


@pytest.fixture
def served(rng):
    cfg = reduce_cfg(get_config("qwen2-0.5b"),
                     num_layers=2, d_model=32, d_ff=64, vocab_size=64)
    params = registry.init_params(cfg, rng)
    eng = ProgressEngine()
    srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64)
    return srv, eng


class TestServeLatencyFields:
    def test_ttft_stamped_exactly_once_on_success(self, served):
        srv, eng = served
        req = CountingGenRequest("r0", np.array([1, 2], np.int32),
                                 max_new_tokens=5)
        srv.submit(req)
        srv.run_until_idle(timeout=240)
        assert req._ttft_stamps == 1           # 5 steps, ONE stamp
        assert req.first_token_at is not None
        assert req.finished_at is not None
        assert req.finished_at >= req.first_token_at >= req.submitted_at
        snap = srv.latency_snapshot()
        assert snap.submitted == 1 and snap.completed == 1
        assert snap.failed == 0 and snap.no_first_token == 0
        assert snap.ttft_ms_mean is not None
        assert snap.latency_ms_mean >= snap.ttft_ms_mean

    def test_failed_before_first_token_null_propagates(self, served):
        """A request whose decode fails before producing any token keeps
        first_token_at=None, gets finished_at, and the snapshot counts
        it instead of faking a TTFT."""
        srv, eng = served
        req = GenRequest("r0", np.array([1], np.int32), max_new_tokens=2)
        with srv._lock:
            slot = srv.slots.assign(req.request_id)
            req.slot_index = slot.index
            req.next_input = 1
            srv._active[slot.index] = req

        def broken(*a, **k):
            raise RuntimeError("device lost")

        srv._jit_decode = broken
        srv._schedule_decode()
        t0 = time.monotonic()
        while not req.done_req.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 30
        assert req.done_req.failed
        assert req.first_token_at is None      # null-propagated, not faked
        assert req.finished_at is not None     # failure path stamps finish
        snap = srv.latency_snapshot()
        assert snap.failed == 1 and snap.no_first_token == 1
        assert snap.ttft_ms_mean is None       # nothing to aggregate
        assert snap.latency_ms_mean is not None

    def test_prefill_failure_records_and_frees_slots(self, served):
        """Prefill raising fails the admitted batch with finished_at set
        and slots released — and later arrivals still serve."""
        srv, eng = served
        real = srv._jit_decode
        srv._jit_decode = lambda *a: (_ for _ in ()).throw(
            RuntimeError("prefill boom"))
        bad = GenRequest("bad", np.array([1, 2, 3], np.int32),
                         max_new_tokens=2)
        done = srv.submit(bad)
        t0 = time.monotonic()
        while not done.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 30
        assert done.failed and "prefill boom" in str(done.exception)
        assert bad.finished_at is not None and bad.first_token_at is None
        assert len(srv.slots.free_slots()) == 4
        assert not srv._prefill_active
        srv._jit_decode = real
        good = srv.submit(GenRequest("good", np.array([1], np.int32),
                                     max_new_tokens=2))
        srv.run_until_idle(timeout=120)
        assert good.is_complete and len(good.value()) == 2
        snap = srv.latency_snapshot()
        assert snap.failed == 1 and snap.completed == 1

    def test_submit_not_blocked_by_prefill_lock(self, served):
        """The serve lock is free while prefill stages its cache: a
        submit() during prefill returns promptly instead of waiting for
        the whole token-by-token prompt loop."""
        srv, eng = served
        in_prefill = threading.Event()
        release = threading.Event()
        real = srv._jit_decode

        def slow_decode(*a, **k):
            in_prefill.set()
            assert release.wait(timeout=30)
            return real(*a, **k)

        srv._jit_decode = slow_decode
        first = srv.submit(GenRequest("a", np.array([1, 2, 3], np.int32),
                                      max_new_tokens=1))
        runner = threading.Thread(target=lambda: srv.run_until_idle(240))
        runner.start()
        try:
            assert in_prefill.wait(timeout=30)
            t0 = time.monotonic()
            srv.submit(GenRequest("b", np.array([4], np.int32),
                                  max_new_tokens=1))
            submit_s = time.monotonic() - t0
            assert submit_s < 1.0, f"submit blocked {submit_s:.1f}s on prefill"
            assert srv._prefill_active          # prefill really was running
        finally:
            release.set()
            runner.join(timeout=240)
        assert first.is_complete


# ---------------------------------------------------------------------------
# Mixed-dtype bucketing (overlap.allreduce_tree / bucket_tree)
# ---------------------------------------------------------------------------

class TestBucketTree:
    def test_buckets_are_single_dtype(self):
        tree = {"a": jnp.ones((4,), jnp.float32),
                "b": jnp.ones((4,), jnp.bfloat16),
                "c": jnp.ones((4,), jnp.float32),
                "d": jnp.ones((4,), jnp.bfloat16)}
        leaves = jax.tree.leaves(tree)
        buckets = bucket_tree(tree, bucket_bytes=1 << 20)
        assert sorted(i for b in buckets for i in b) == list(range(4))
        for b in buckets:
            dts = {jnp.dtype(leaves[i].dtype) for i in b}
            assert len(dts) == 1, f"mixed-dtype bucket {b}: {dts}"

    def test_size_limit_still_respected_per_dtype(self):
        tree = [jnp.ones((1024,), jnp.float32) for _ in range(4)]
        buckets = bucket_tree(tree, bucket_bytes=4096)
        assert len(buckets) == 4               # each leaf hits the cap

    def test_non_array_leaf_rejected_eagerly(self):
        with pytest.raises(TypeError, match="leaf 1 is float"):
            bucket_tree([jnp.ones((2,)), 3.14, jnp.ones((2,))])


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_mixed_dtype_allreduce_tree_matches_psum(n_devices):
    """Bucketed user-schedule allreduce_tree on a mixed f32/bf16 tree:
    per-leaf dtype preserved (no silent upcast) and values match the
    per-leaf native psum within the leaf dtype's tolerance."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.collectives.overlap import allreduce_tree

        n = {n_devices}
        mesh = compat.make_mesh((n,), ("x",))
        key = jax.random.PRNGKey(0)
        tree = {{
            "w32": jax.random.normal(key, (n, 3, 8), jnp.float32),
            "w16": jax.random.normal(key, (n, 2, 5)).astype(jnp.bfloat16),
            "b32": jax.random.normal(key, (n, 7), jnp.float32),
            "b16": jax.random.normal(key, (n, 4)).astype(jnp.bfloat16),
        }}

        def reduced(algorithm):
            fn = lambda t: allreduce_tree(t, "x", algorithm)
            return jax.jit(compat.shard_map(
                fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(tree)

        native = reduced("psum")
        for alg in ("ring", "recursive_doubling"):
            user = reduced(alg)
            for k in tree:
                nat, usr = native[k], user[k]
                assert usr.dtype == tree[k].dtype, (k, usr.dtype)
                tol = 1e-5 if usr.dtype == jnp.float32 else 0.05
                np.testing.assert_allclose(
                    np.asarray(usr, np.float32), np.asarray(nat, np.float32),
                    atol=tol, rtol=tol, err_msg=f"{{alg}}/{{k}}")
        print("MIXED_DTYPE_TREE_OK")
    """, n_devices=n_devices)
    assert "MIXED_DTYPE_TREE_OK" in out


# ---------------------------------------------------------------------------
# Trend gate: serve_decode rows are tracked, serve_gain ratios are not
# ---------------------------------------------------------------------------

class TestTrendServeRows:
    def _summary(self, rows):
        return {"schema": "repro-bench-v1", "git_rev": "x",
                "rows": [{"name": n, "us_per_call": v, "derived": ""}
                         for n, v in rows]}

    def test_serve_rows_in_default_prefixes(self, tmp_path):
        import json
        prev = tmp_path / "prev.json"
        cur = tmp_path / "cur.json"
        prev.write_text(json.dumps(self._summary(
            [("serve_decode_user_m2", 100.0),
             ("serve_gain_user_vs_native_m2", 1.5),
             ("fig7_pending_1", 1.0)])))
        cur.write_text(json.dumps(self._summary(
            [("serve_decode_user_m2", 200.0),          # 2x slower
             ("serve_gain_user_vs_native_m2", 0.1),    # ratio: untracked
             ("fig7_pending_1", 1.0)])))
        prev_rows = trend.load_rows(str(prev), trend.DEFAULT_PREFIXES)
        cur_rows = trend.load_rows(str(cur), trend.DEFAULT_PREFIXES)
        assert "serve_decode_user_m2" in prev_rows
        assert "serve_gain_user_vs_native_m2" not in prev_rows
        entries = trend.compare(prev_rows, cur_rows, 0.2)
        by_name = {e["name"]: e for e in entries}
        assert by_name["serve_decode_user_m2"]["status"] == "regressed"
        assert by_name["fig7_pending_1"]["status"] == "ok"
        rc = trend.main(["--current", str(cur), "--previous", str(prev)])
        assert rc == 1                         # regression annotates
