"""End-to-end trainer (with resume) + continuous-batching server tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ProgressEngine, ProgressExecutor, Request, stats
from repro.data.pipeline import PrefetchPipeline, SyntheticLM
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Trainer, TrainLoopConfig
from tests.conftest import reduce_cfg


def tiny_setup(tmp_path, rng, steps=6, resume=True):
    cfg = reduce_cfg(get_config("smollm-360m"),
                     num_layers=2, d_model=32, d_ff=64, vocab_size=64)
    params = registry.init_params(cfg, rng)
    ocfg = opt_mod.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
    opt_state = opt_mod.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, aux), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, om = opt_mod.apply(ocfg, opt_state, params, grads)
        return params, opt_state, dict(loss=loss, **om)

    eng = ProgressEngine()
    pipe = PrefetchPipeline(SyntheticLM(64, 16, 4, seed=3), eng, depth=2)
    tl = TrainLoopConfig(total_steps=steps, checkpoint_every=3,
                         checkpoint_dir=str(tmp_path / "ckpt"),
                         log_every=1, resume=resume)
    return Trainer(step_fn, params, opt_state, pipe, tl, engine=eng), pipe


class TestTrainer:
    def test_loss_decreases(self, tmp_path, rng):
        tr, pipe = tiny_setup(tmp_path, rng, steps=12)
        log = tr.run()
        pipe.close()
        first, last = log[0]["loss"], log[-1]["loss"]
        assert np.isfinite(first) and np.isfinite(last)
        assert last < first, (first, last)

    def test_checkpoint_restart_resumes(self, tmp_path, rng):
        tr, pipe = tiny_setup(tmp_path, rng, steps=4)
        tr.run()
        pipe.close()
        assert tr.ckpt.latest_step() == 3
        # "crash" and restart: new trainer resumes from step 4
        tr2, pipe2 = tiny_setup(tmp_path, rng, steps=6)
        log = tr2.run()
        pipe2.close()
        assert tr2.start_step == 4
        assert log[0]["step"] >= 4

    def test_straggler_records(self, tmp_path, rng):
        tr, pipe = tiny_setup(tmp_path, rng, steps=3)
        tr.run()
        pipe.close()
        assert len(tr.straggler.history) == 3


class TestServeEngine:
    @pytest.fixture
    def served(self, rng):
        cfg = reduce_cfg(get_config("qwen2-0.5b"),
                         num_layers=2, d_model=32, d_ff=64, vocab_size=64)
        params = registry.init_params(cfg, rng)
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64)
        return srv, eng

    def test_single_request(self, served):
        srv, eng = served
        req = GenRequest("r0", np.array([1, 2, 3], np.int32), max_new_tokens=5)
        done = srv.submit(req)
        srv.run_until_idle(timeout=120)
        assert done.is_complete
        assert len(done.value()) == 5
        assert all(0 <= t < 64 for t in done.value())

    def test_continuous_batching_many_requests(self, served):
        srv, eng = served
        reqs = [GenRequest(f"r{i}", np.array([i + 1, i + 2], np.int32),
                           max_new_tokens=4) for i in range(7)]
        dones = [srv.submit(r) for r in reqs]    # 7 requests, 4 slots
        srv.run_until_idle(timeout=240)
        assert all(d.is_complete for d in dones)
        assert all(len(d.value()) == 4 for d in dones)
        # slots were reused: more requests than slots all completed
        assert len(srv.slots.free_slots()) == 4

    def test_greedy_determinism(self, served):
        srv, eng = served
        r1 = GenRequest("a", np.array([5, 6], np.int32), max_new_tokens=4)
        d1 = srv.submit(r1)
        srv.run_until_idle(timeout=120)
        r2 = GenRequest("b", np.array([5, 6], np.int32), max_new_tokens=4)
        d2 = srv.submit(r2)
        srv.run_until_idle(timeout=120)
        assert d1.value() == d2.value()

    def test_latency_metrics_recorded(self, served):
        srv, eng = served
        req = GenRequest("r0", np.array([1], np.int32), max_new_tokens=2)
        srv.submit(req)
        srv.run_until_idle(timeout=120)
        assert req.first_token_at is not None
        assert req.finished_at is not None
        assert req.finished_at >= req.first_token_at

    def test_broken_injected_task_does_not_halt_serving(self, served):
        """A raising task on a serve stream is dropped; the bridge stays
        registered and serving continues (regression: the engine's
        subsystem isolation used to unregister the bridge)."""
        srv, eng = served
        eng.async_start(lambda t: 1 / 0, None, srv.decode_stream)
        req = GenRequest("r0", np.array([1, 2], np.int32), max_new_tokens=2)
        done = srv.submit(req)
        srv.run_until_idle(timeout=120)
        assert done.is_complete and len(done.value()) == 2
        assert len(srv.decode_stream.task_errors) == 1
        assert srv._sub is not None              # bridge survived

    def test_close_drains_serve_streams(self, served):
        srv, eng = served
        req = GenRequest("r0", np.array([1, 2], np.int32), max_new_tokens=2)
        srv.submit(req)
        srv.run_until_idle(timeout=120)
        srv.close(timeout=60)
        assert srv.admit_stream.pending == 0
        assert srv.decode_stream.pending == 0
        assert srv.continuations.ready == 0 and srv.continuations.pending == 0
        with pytest.raises(RuntimeError):
            srv.submit(GenRequest("late", np.array([1], np.int32)))

    def test_decode_completions_delivered_via_continuations(self, served):
        """The event-driven acceptance: every fused decode step's
        completion is delivered by continuation execution (counters
        nonzero and equal to the step count), not by a polling consumer."""
        srv, eng = served
        reqs = [GenRequest(f"r{i}", np.array([1, 2], np.int32),
                           max_new_tokens=3) for i in range(3)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_idle(timeout=240)
        snap = stats.collect(eng)
        cq = snap.continuation_queue("serve-cont")
        assert srv.steps > 0
        assert cq.executed == srv.steps        # one detokenize per step
        assert cq.failed == 0

    def test_no_busy_wait_when_idle(self, served):
        """No polling loop in the lifecycle: once the backlog is served,
        the serve streams are EMPTY — further progress calls poll zero
        tasks (the old perpetual admit/decode tasks would spin forever)."""
        srv, eng = served
        req = GenRequest("r0", np.array([1, 2], np.int32), max_new_tokens=2)
        srv.submit(req)
        srv.run_until_idle(timeout=120)
        polls_before = (srv.admit_stream.polls, srv.decode_stream.polls)
        spins_before = (srv.admit_stream.idle_spins,
                        srv.decode_stream.idle_spins)
        for _ in range(50):
            eng.progress()
        assert (srv.admit_stream.polls, srv.decode_stream.polls) == polls_before
        assert (srv.admit_stream.idle_spins,
                srv.decode_stream.idle_spins) == spins_before

    def test_admission_deferred_while_step_inflight(self, served):
        """Prefill writes slots.cache; an in-flight step's continuation
        overwrites it with the step's output cache.  Admission must
        therefore defer while a step is in flight (the continuation
        admits between steps) or mid-step arrivals lose their prompt KV."""
        srv, eng = served
        with srv._lock:
            srv._decode_inflight = ("sentinel", "sentinel")
        srv.submit(GenRequest("r", np.array([1], np.int32), max_new_tokens=1))
        assert srv._admit() is False           # deferred, not prefetched
        assert len(srv._arrivals) == 1         # still queued
        with srv._lock:
            srv._decode_inflight = None
        assert srv._admit() is True            # admitted between steps
        srv.run_until_idle(timeout=120)

    def test_decode_dispatch_failure_fails_requests(self, served):
        """Failure continuation: a decode step that cannot even dispatch
        fails every in-flight request with the step's exception instead
        of hanging the server."""
        srv, eng = served
        req = GenRequest("r0", np.array([1], np.int32), max_new_tokens=2)
        with srv._lock:
            slot = srv.slots.assign(req.request_id)
            req.slot_index = slot.index
            req.next_input = 1
            srv._active[slot.index] = req

        def broken(*a, **k):
            raise RuntimeError("device lost")

        srv._jit_decode = broken
        srv._schedule_decode()
        t0 = time.monotonic()
        while not req.done_req.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 30
        assert req.done_req.failed
        assert isinstance(req.done_req.exception, RuntimeError)
        assert len(srv.slots.free_slots()) == 4    # slot released
        assert len(srv.decode_errors) == 1

    def test_harvest_failure_fails_requests(self, served):
        """Async device errors surface at materialization, not dispatch:
        a step whose logits blow up during detokenize must fail the
        in-flight requests (failure path), not wedge the server."""
        srv, eng = served
        req = GenRequest("r0", np.array([1], np.int32), max_new_tokens=2)
        with srv._lock:
            slot = srv.slots.assign(req.request_id)
            req.slot_index = slot.index
            req.next_input = 1
            srv._active[slot.index] = req

        class BoomLogits:
            def __getitem__(self, key):
                raise RuntimeError("device preempted")

        step = Request(tag="decode-step")
        with srv._lock:
            srv._current_step = step
            srv._decode_inflight = (BoomLogits(), "cache")
        step.complete((BoomLogits(), srv.slots.cache))
        srv._attach_step(step)
        t0 = time.monotonic()
        while not req.done_req.is_complete:
            eng.progress()
            assert time.monotonic() - t0 < 30
        assert req.done_req.failed
        assert "preempted" in str(req.done_req.exception)
        assert len(srv.slots.free_slots()) == 4

    def test_inline_continuation_policy_serves(self, rng):
        cfg = reduce_cfg(get_config("qwen2-0.5b"),
                         num_layers=2, d_model=32, d_ff=64, vocab_size=64)
        params = registry.init_params(cfg, rng)
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=64,
                          continuation_policy="inline")
        reqs = [GenRequest(f"r{i}", np.array([1, 2], np.int32),
                           max_new_tokens=3) for i in range(3)]
        dones = [srv.submit(r) for r in reqs]
        srv.run_until_idle(timeout=240)
        assert all(d.is_complete for d in dones)
        assert srv.continuations.deferred == 0     # inline: never queued
        assert srv.continuations.executed == srv.steps


class TestServeEngineOnExecutor:
    def test_serves_on_background_workers(self, rng):
        """The serve streams adopted by a 2-worker executor: the main
        thread only submits and waits; progress happens on the workers."""
        cfg = reduce_cfg(get_config("qwen2-0.5b"),
                         num_layers=2, d_model=32, d_ff=64, vocab_size=64)
        params = registry.init_params(cfg, rng)
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2, steal=False)
        srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64,
                          executor=ex)
        ex.start()
        reqs = [GenRequest(f"r{i}", np.array([i + 1, i + 2], np.int32),
                           max_new_tokens=4) for i in range(6)]
        dones = [srv.submit(r) for r in reqs]    # 6 requests, 4 slots
        done_idx = eng.wait_some(dones, min_count=len(dones), timeout=240)
        assert len(done_idx) == 6
        srv.run_until_idle(timeout=60)
        snap = stats.collect(eng, ex)      # before close frees the streams
        srv.close(timeout=60)
        ex.shutdown(drain=True, timeout=60)
        assert all(d.is_complete for d in dones)
        assert all(len(d.value()) == 4 for d in dones)
        assert len(srv.slots.free_slots()) == 4
        assert snap.stream("serve-admit").completions >= 1
        assert snap.stream("serve-decode").completions >= 1
        # close handed the streams back to the engine
        with eng._lock:
            names = [s.name for s in eng._streams]
        assert "serve-admit" not in names and "serve-decode" not in names

    def test_unstarted_executor_serves_inline(self, rng):
        """Forgetting executor.start() must degrade to inline progress,
        not hang until TimeoutError (regression)."""
        cfg = reduce_cfg(get_config("qwen2-0.5b"),
                         num_layers=2, d_model=32, d_ff=64, vocab_size=64)
        params = registry.init_params(cfg, rng)
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, num_workers=2)    # never started
        srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=64,
                          executor=ex)
        done = srv.submit(GenRequest("r0", np.array([1, 2], np.int32),
                                     max_new_tokens=2))
        srv.run_until_idle(timeout=120)
        assert done.is_complete and len(done.value()) == 2

    def test_executor_matches_caller_driven_output(self, rng):
        cfg = reduce_cfg(get_config("qwen2-0.5b"),
                         num_layers=2, d_model=32, d_ff=64, vocab_size=64)
        params = registry.init_params(cfg, rng)

        def serve_once(executor_workers):
            eng = ProgressEngine()
            ex = (ProgressExecutor(eng, executor_workers).start()
                  if executor_workers else None)
            srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64,
                              executor=ex)
            r = GenRequest("a", np.array([5, 6], np.int32), max_new_tokens=4)
            d = srv.submit(r)
            srv.run_until_idle(timeout=120)
            srv.close(timeout=60)
            if ex is not None:
                ex.shutdown(drain=True, timeout=60)
            return d.value()

        assert serve_once(0) == serve_once(2)    # greedy: same tokens


class TestTrainerWithProgressWorkers:
    def test_progress_workers_train(self, tmp_path, rng):
        tr, pipe = tiny_setup(tmp_path, rng, steps=4)
        tr.cfg.progress_workers = 2
        log = tr.run()
        pipe.close()
        assert len(log) == 4
        assert all(np.isfinite(m["loss"]) for m in log)
        # executor detached again after run()
        assert tr.engine.executor is None
